"""Model assembly: Trident-vs-Plain consistency, recurrent blocks, serving.

Heavier tests (scan-body compiles) are consolidated here; per-arch smoke
lives in test_arch_smoke.py.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.context import make_context
from repro.nn.engine import TridentEngine, PlainEngine
from repro.nn import model as M
from repro.nn import recurrent as RC

LSB = 2.0 ** -13


def tiny(family, **kw):
    base = dict(name="tiny", family=family, n_layers=2, d_model=16,
                n_heads=4, n_kv_heads=2, d_ff=32, vocab=64, seq_chunk=4,
                remat=False, rope_theta=1e4)
    base.update(kw)
    return M.ModelConfig(**base)


class TestRecurrentBlocks:
    def test_retention_trident_vs_plain(self, rng):
        B, S, D, H = 2, 16, 24, 4
        cfg = RC.RetentionConfig(d_model=D, n_heads=H, d_k=8, d_v=D // H,
                                 seq_chunk=4)
        params_np = RC.retention_init(rng, cfg)
        x = rng.randn(B, S, D) * 0.5
        dy = rng.randn(B, S, D) * 0.1

        pe = PlainEngine()
        pp = {k: jnp.asarray(v, jnp.float32) for k, v in params_np.items()}
        y_p, cache_p, _ = RC.retention_fwd(pe, pp, cfg,
                                           jnp.asarray(x, jnp.float32))
        dx_p, g_p = RC.retention_bwd(pe, pp, cfg, cache_p,
                                     jnp.asarray(dy, jnp.float32))

        te = TridentEngine(make_context(seed=1))
        tp = {k: te.from_plain(v) for k, v in params_np.items()}
        y_t, cache_t, _ = RC.retention_fwd(te, tp, cfg, te.from_plain(x))
        assert np.abs(np.asarray(te.to_plain(y_t))
                      - np.asarray(y_p)).max() < 0.01
        dx_t, g_t = RC.retention_bwd(te, tp, cfg, cache_t,
                                     te.from_plain(dy))
        assert np.abs(np.asarray(te.to_plain(dx_t))
                      - np.asarray(dx_p)).max() < 0.05
        for k in g_p:
            e = np.abs(np.asarray(te.to_plain(g_t[k]))
                       - np.asarray(g_p[k])).max()
            assert e < 0.05, (k, e)

    def test_retention_plain_matches_autograd(self, rng):
        B, S, D, H = 2, 8, 16, 4
        cfg = RC.RetentionConfig(d_model=D, n_heads=H, d_k=8, d_v=D // H,
                                 seq_chunk=4)
        pp = {k: jnp.asarray(v, jnp.float32)
              for k, v in RC.retention_init(rng, cfg).items()}
        x = jnp.asarray(rng.randn(B, S, D) * 0.5, jnp.float32)
        dy = jnp.asarray(rng.randn(B, S, D), jnp.float32)
        pe = PlainEngine()
        _, cache, _ = RC.retention_fwd(pe, pp, cfg, x)
        _, g = RC.retention_bwd(pe, pp, cfg, cache, dy)

        def f(w):
            y, _, _ = RC.retention_fwd(pe, {**pp, "wq": w}, cfg, x)
            return jnp.sum(y * dy)
        gnum = jax.grad(f)(pp["wq"])
        np.testing.assert_allclose(np.asarray(gnum), np.asarray(g["wq"]),
                                   atol=1e-4)

    def test_retention_step_matches_fwd(self, rng):
        B, S, D, H = 2, 8, 16, 4
        cfg = RC.RetentionConfig(d_model=D, n_heads=H, d_k=8, d_v=D // H,
                                 seq_chunk=4)
        pe = PlainEngine()
        pp = {k: jnp.asarray(v, jnp.float32)
              for k, v in RC.retention_init(rng, cfg).items()}
        x = jnp.asarray(rng.randn(B, S, D) * 0.5, jnp.float32)
        y_full, _, _ = RC.retention_fwd(pe, pp, cfg, x)
        st = pe.zeros((B, H, 8, D // H))
        outs = []
        for t in range(S):
            yt, st = RC.retention_step(pe, pp, cfg, x[:, t:t + 1], st)
            outs.append(np.asarray(yt))
        np.testing.assert_allclose(np.concatenate(outs, 1),
                                   np.asarray(y_full), atol=1e-5)

    def test_slstm_trident_vs_plain(self, rng):
        B, S, D, H = 2, 16, 24, 4
        cfg = RC.SLSTMConfig(d_model=D, n_heads=H, seq_chunk=4)
        params_np = RC.slstm_init(rng, cfg)
        x = rng.randn(B, S, D) * 0.5
        pe = PlainEngine()
        pp = {k: jnp.asarray(v, jnp.float32) for k, v in params_np.items()}
        y_p, _, _ = RC.slstm_fwd(pe, pp, cfg, jnp.asarray(x, jnp.float32))
        te = TridentEngine(make_context(seed=2))
        tp = {k: te.from_plain(v) for k, v in params_np.items()}
        y_t, _, _ = RC.slstm_fwd(te, tp, cfg, te.from_plain(x))
        assert np.abs(np.asarray(te.to_plain(y_t))
                      - np.asarray(y_p)).max() < 0.02

    def test_slstm_step_matches_fwd(self, rng):
        B, S, D, H = 2, 8, 16, 4
        cfg = RC.SLSTMConfig(d_model=D, n_heads=H, seq_chunk=4)
        pe = PlainEngine()
        pp = {k: jnp.asarray(v, jnp.float32)
              for k, v in RC.slstm_init(rng, cfg).items()}
        x = jnp.asarray(rng.randn(B, S, D) * 0.5, jnp.float32)
        y_full, _, _ = RC.slstm_fwd(pe, pp, cfg, x)
        st = pe.zeros((B, H, 1, D // H))
        outs = []
        for t in range(S):
            yt, st = RC.slstm_step(pe, pp, cfg, x[:, t:t + 1], st)
            outs.append(np.asarray(yt))
        np.testing.assert_allclose(np.concatenate(outs, 1),
                                   np.asarray(y_full), atol=1e-5)


class TestModelEndToEnd:
    """One full Trident-vs-Plain train step (dense family; the other
    families are covered structurally by the arch smokes)."""

    def test_dense_train_step_consistency(self, rng):
        """The guarded truncation pair (core.protocols.TRUNC_GUARD) bounds
        the Fig. 18 error to its 1-LSB probabilistic level, which keeps the
        tiny-scale loss/grad agreement inside the tolerances below."""
        cfg = tiny("dense")
        params_np = M.init_params(cfg, seed=1)
        ids = rng.randint(0, cfg.vocab, (2, 8))
        labels = rng.randint(0, cfg.vocab, (2, 8))

        pe = PlainEngine()
        pp = M.params_to_engine(pe, params_np)
        loss_p, grads_p = M.loss_and_grads(pe, cfg, pp, ids, labels)

        ctx = make_context(seed=2)
        te = TridentEngine(ctx)
        tp = M.params_to_engine(te, params_np)
        loss_t, grads_t = M.loss_and_grads(te, cfg, tp, ids, labels)
        assert abs(float(loss_p) - float(loss_t)) < 0.02
        assert not bool(ctx.abort_flag())
        # spot-check the lm_head gradient DIRECTION.  At this tiny test
        # scale dlogits = (p - onehot)/(B*S) ~ 1e-3/element while the
        # fixed-point LSB is 2^-13 = 1.2e-4 and the smx denominator floor
        # (1e-2 -> inv up to 1e2) further amplifies quantization noise:
        # per-element SNR is only ~8:1, so cosine similarity ~0.9 is the
        # expected noise floor, not an implementation error (the full-scale
        # convergence tests in test_train.py are the functional check).
        g_p = np.asarray(grads_p["lm_head"]["w"]).ravel()
        g_t = np.asarray(te.to_plain(grads_t["lm_head"]["w"])).ravel()
        cos = np.dot(g_p, g_t) / (np.linalg.norm(g_p) *
                                  np.linalg.norm(g_t) + 1e-12)
        assert cos > 0.75, cos
        assert np.abs(g_t - g_p).max() < 0.5

    def test_remat_matches_noremat_plain(self, rng):
        import dataclasses
        cfg = tiny("dense")
        cfg_r = dataclasses.replace(cfg, remat=True)
        params_np = M.init_params(cfg, seed=3)
        ids = rng.randint(0, cfg.vocab, (2, 8))
        labels = rng.randint(0, cfg.vocab, (2, 8))
        pe = PlainEngine()
        pp = M.params_to_engine(pe, params_np)
        l1, g1 = M.loss_and_grads(pe, cfg, pp, ids, labels)
        l2, g2 = M.loss_and_grads(pe, cfg_r, pp, ids, labels)
        assert abs(float(l1) - float(l2)) < 1e-5
        np.testing.assert_allclose(np.asarray(g1["lm_head"]["w"]),
                                   np.asarray(g2["lm_head"]["w"]),
                                   atol=1e-5)

    def test_prefill_matches_forward_plain(self, rng):
        cfg = tiny("dense", q_chunk=4)
        params_np = M.init_params(cfg, seed=4)
        ids = rng.randint(0, cfg.vocab, (2, 8))
        pe = PlainEngine()
        pp = M.params_to_engine(pe, params_np)
        logits, _ = M.forward(pe, cfg, pp, ids)
        last_logits, caches = M.serve_prefill(pe, cfg, pp, ids)
        np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                                   np.asarray(logits[:, -1]), atol=1e-4)

    def test_decode_matches_forward_plain(self, rng):
        """Prefill S tokens then decode token S: logits must equal a full
        forward over S+1 tokens at the last position."""
        cfg = tiny("dense")
        params_np = M.init_params(cfg, seed=5)
        ids = rng.randint(0, cfg.vocab, (2, 9))
        pe = PlainEngine()
        pp = M.params_to_engine(pe, params_np)
        _, caches = M.serve_prefill(pe, cfg, pp, ids[:, :8])
        logits_dec, _ = M.serve_decode(pe, cfg, pp, ids[:, 8:9], caches,
                                       pos=8)
        logits_full, _ = M.forward(pe, cfg, pp, ids)
        np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                                   np.asarray(logits_full[:, -1]),
                                   atol=1e-4)

    def test_ssm_decode_matches_forward_plain(self, rng):
        # seq_chunk=1 so the 9-token comparison forward divides evenly
        cfg = tiny("ssm", ssm_state=8, n_kv_heads=4, seq_chunk=1)
        params_np = M.init_params(cfg, seed=6)
        ids = rng.randint(0, cfg.vocab, (2, 9))
        pe = PlainEngine()
        pp = M.params_to_engine(pe, params_np)
        _, caches = M.serve_prefill(pe, cfg, pp, ids[:, :8])
        logits_dec, _ = M.serve_decode(pe, cfg, pp, ids[:, 8:9], caches,
                                       pos=8)
        logits_full, _ = M.forward(pe, cfg, pp, ids)
        np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                                   np.asarray(logits_full[:, -1]),
                                   atol=1e-3)

    def test_kv_compression_roundtrip(self, rng):
        from repro.nn.model import kv_compress, kv_expand
        te = TridentEngine(make_context(seed=7))
        x = te.from_plain(rng.randn(2, 2, 4, 8))
        back = kv_expand(te, kv_compress(te, x))
        np.testing.assert_array_equal(np.asarray(back.reveal()),
                                      np.asarray(x.reveal()))
