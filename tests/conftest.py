"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests and benches
must see the 1 real CPU device; only launch/dryrun.py forces 512."""
import numpy as np
import pytest

from repro.core.context import make_context
from repro.core.ring import RING64, RING32


@pytest.fixture
def ctx():
    return make_context(RING64, seed=7)


@pytest.fixture
def ctx32():
    return make_context(RING32, seed=7)


@pytest.fixture
def rng():
    return np.random.RandomState(1234)
