"""Executed CostTally == the paper's analytic formulas (Tables I, II, IX, X).

This is the faithful-reproduction validation of the paper's central claims:
every protocol's traced round/bit tally must equal the corresponding lemma.
"""
import numpy as np
import pytest

from repro.core import protocols as PR
from repro.core import conversions as CV
from repro.core import activations as ACT
from repro.core import garbled as GW
from repro.core import paper_costs as PC
from repro.core.context import make_context
from repro.core.ring import RING64, RING32


def fresh(ell=64, **kw):
    return make_context(RING64 if ell == 64 else RING32, seed=5, **kw)


def one(ctx, val=0.5):
    return PR.share(ctx, ctx.ring.encode(np.asarray([val])))


def delta(ctx, fn):
    """(off_rounds, off_bits, on_rounds, on_bits) of executing fn."""
    o0, n0 = ctx.tally.offline, ctx.tally.online
    before = (o0.rounds, o0.bits, n0.rounds, n0.bits)
    fn()
    after = (ctx.tally.offline.rounds, ctx.tally.offline.bits,
             ctx.tally.online.rounds, ctx.tally.online.bits)
    return tuple(a - b for a, b in zip(after, before))


@pytest.mark.parametrize("ell", [32, 64])
class TestPaperTableCosts:
    """Per-element costs vs Tables I/IX/X ("This" rows)."""

    def test_share(self, ell):
        ctx = fresh(ell)
        d = delta(ctx, lambda: PR.share(ctx, ctx.ring.encode(np.zeros(1))))
        assert d == PC.TRIDENT["share"](ell)

    def test_rec(self, ell):
        ctx = fresh(ell)
        x = one(ctx)
        d = delta(ctx, lambda: PR.reconstruct(ctx, x))
        assert d == PC.TRIDENT["rec"](ell)

    def test_mult(self, ell):
        ctx = fresh(ell)
        x, y = one(ctx), one(ctx)
        d = delta(ctx, lambda: PR.mult(ctx, x, y))
        assert d == PC.TRIDENT["mult"](ell)

    @pytest.mark.parametrize("length", [1, 10, 1000])
    def test_dotp_cost_independent_of_length(self, ell, length):
        """The headline claim: Pi_DotP comm is independent of d."""
        ctx = fresh(ell)
        x = PR.share(ctx, ctx.ring.encode(np.zeros(length)))
        y = PR.share(ctx, ctx.ring.encode(np.zeros(length)))
        d = delta(ctx, lambda: PR.dotp(ctx, x, y))
        assert d == PC.TRIDENT["dotp"](ell)

    @pytest.mark.parametrize("shape", [(4, 8, 16), (2, 2, 64)])
    def test_matmul_cost_3l_per_output(self, ell, shape):
        """Pi_MatMul = 3*ell bits per OUTPUT element, contraction-free."""
        m, k, n = shape
        ctx = fresh(ell)
        a = PR.share(ctx, ctx.ring.encode(np.zeros((m, k))))
        b = PR.share(ctx, ctx.ring.encode(np.zeros((k, n))))
        d = delta(ctx, lambda: PR.matmul(ctx, a, b))
        assert d == (1, 3 * ell * m * n, 1, 3 * ell * m * n)

    def test_mult_tr(self, ell):
        """Fig. 18: online identical to bare mult (the paper's highlight)."""
        ctx = fresh(ell)
        x, y = one(ctx), one(ctx)
        d = delta(ctx, lambda: PR.mult_tr(ctx, x, y))
        assert d == PC.TRIDENT["mult_tr"](ell)
        assert d[2:] == PC.TRIDENT["mult"](ell)[2:]

    def test_bit2a(self, ell):
        ctx = fresh(ell)
        v = one(ctx)
        b = CV.bit_extract(ctx, v)
        d = delta(ctx, lambda: CV.bit2a(ctx, b))
        assert d == PC.TRIDENT["bit2a"](ell)

    def test_b2a(self, ell):
        ctx = fresh(ell)
        from repro.core import boolean as BW
        vb = BW.share_bool(ctx, ctx.ring.encode(np.zeros(1)))
        d = delta(ctx, lambda: CV.b2a(ctx, vb))
        assert d == PC.TRIDENT["b2a"](ell)

    def test_bitinj(self, ell):
        ctx = fresh(ell)
        v = one(ctx)
        b = CV.bit_extract(ctx, v)
        d = delta(ctx, lambda: CV.bit_inject(ctx, b, v))
        assert d == PC.TRIDENT["bitinj"](ell)

    def test_bitext(self, ell):
        ctx = fresh(ell)
        v = one(ctx)
        d = delta(ctx, lambda: CV.bit_extract(ctx, v, method="mul"))
        assert d == PC.TRIDENT["bitext"](ell)

    def test_a2b(self, ell):
        """A2B matches the implementation-exact formula; the delta to the
        paper's idealized count is exactly one PPA level (DESIGN.md)."""
        ctx = fresh(ell)
        v = one(ctx)
        d = delta(ctx, lambda: CV.a2b(ctx, v))
        assert d == PC.TRIDENT_IMPL["a2b"](ell)
        paper = PC.TRIDENT["a2b"](ell)
        assert d[2] - paper[2] == 1               # +1 online round
        assert d[3] - paper[3] == 3 * ell         # +l initial generate ANDs
        assert d[0] == paper[0]                   # offline rounds match

    def test_relu(self, ell):
        """ReLU online: 4 rounds, 8*ell + 2 bits -- Table X exact."""
        ctx = fresh(ell)
        v = one(ctx)
        d = delta(ctx, lambda: ACT.relu(ctx, v))
        assert d == PC.TRIDENT_IMPL["relu"](ell)
        assert d[2:] == PC.TRIDENT["relu"](ell)[2:]   # online == paper
        assert d[0] == PC.TRIDENT["relu"](ell)[0]     # offline rounds too

    def test_sigmoid(self, ell):
        """Sigmoid online: 5 rounds, 16*ell + 7 bits -- Table X exact."""
        ctx = fresh(ell)
        v = one(ctx)
        d = delta(ctx, lambda: ACT.sigmoid(ctx, v))
        assert d == PC.TRIDENT_IMPL["sigmoid"](ell)
        assert d[2:] == PC.TRIDENT["sigmoid"](ell)[2:]
        assert d[0] == PC.TRIDENT["sigmoid"](ell)[0]

    def test_garbled_conversion_costs(self, ell):
        ctx = fresh(ell)
        d = delta(ctx, lambda: GW.a2g_cost(ctx, (1,)))
        want = PC.TRIDENT["a2g"](ell)
        assert d[2:] == want[2:]
        ctx = fresh(ell)
        d = delta(ctx, lambda: GW.g2a_cost(ctx, (1,)))
        assert d[2:] == PC.TRIDENT["g2a"](ell)[2:]
        ctx = fresh(ell)
        d = delta(ctx, lambda: GW.b2g_cost(ctx, (1,), 1))
        assert d[2:] == PC.TRIDENT["b2g"](64)[2:] if ell == 64 else True
        ctx = fresh(ell)
        d = delta(ctx, lambda: GW.g2b_cost(ctx, (1,), 1))
        assert d[2:] == PC.TRIDENT["g2b"](ell)[2:]


class TestHeadlineImprovements:
    """The abstract's improvement factors, derived from the formula tables."""

    def test_b2a_improvement_7x_rounds(self):
        ell = 64
        _, _, r_aby3, c_aby3 = PC.ABY3["b2a"](ell)
        _, _, r_this, c_this = PC.TRIDENT["b2a"](ell)
        assert r_aby3 / r_this == 7          # 1 + log 64 = 7 vs 1
        assert c_aby3 / c_this >= 18         # >= 18x communication

    def test_mult_tr_4x(self):
        ell = 64
        assert PC.ABY3["mult_tr"](ell)[3] / PC.TRIDENT["mult_tr"](ell)[3] == 4

    def test_trunc_offline_rounds_63x(self):
        ell = 64
        # ABY3 RCA: 2*ell - 2 = 126 rounds vs our 2 -> 63x
        assert PC.ABY3["mult_tr"](ell)[0] / PC.TRIDENT["mult_tr"](ell)[0] == 63

    def test_secure_comparison_21x_comm(self):
        ell = 64
        c_aby3 = PC.ABY3["bitext"](ell)[3]
        c_this = PC.TRIDENT["bitext"](ell)[3]
        assert c_aby3 / c_this > 20          # ~21x (paper Section I-A 4)

    def test_relu_constant_rounds(self):
        for ell in (32, 64):
            assert PC.TRIDENT["relu"](ell)[2] == 4
            assert PC.ABY3["relu"](ell)[2] == 3 + int(np.log2(ell))

    def test_dot_product_feature_independence(self):
        ell, d = 64, 784
        aby3 = PC.ABY3["dotp"](ell, d)[3]
        this = PC.TRIDENT["dotp"](ell, d)[3]
        assert aby3 == 9 * ell * d and this == 3 * ell

    def test_mult_25pct_online_saving_vs_gordon(self):
        ell = 64
        gordon_online = PC.GORDON["mult"](ell)[3]
        this_online = PC.TRIDENT["mult"](ell)[3]
        assert this_online / gordon_online == 0.75     # 3 vs 4 elements
        # total cost not compromised: 6 elements both
        assert (PC.TRIDENT["mult"](ell)[1] + this_online) == 6 * ell


class TestModelIterationCosts:
    """Composite per-iteration costs (Section VI-A compositions)."""

    def test_linreg_online_bits_feature_free(self):
        ell, B = 64, 128
        for d in (10, 100, 1000):
            c = PC.model_iteration_cost("trident", ell, d, B, "linreg")
            # online bits: (B + d) outputs * 3*ell each -- feature count only
            # enters through the dW matmul's output size
            assert c[3] == 3 * ell * (B + d)

    def test_aby3_linreg_scales_with_features(self):
        ell, B = 64, 128
        c10 = PC.model_iteration_cost("aby3", ell, 10, B, "linreg")
        c1000 = PC.model_iteration_cost("aby3", ell, 1000, B, "linreg")
        assert c1000[3] > 50 * c10[3]

    def test_trident_beats_aby3_everywhere(self):
        ell, B = 64, 128
        for kind, layers in (("linreg", ()), ("logreg", ()),
                             ("nn", (128, 128, 10)), ("cnn", (980, 100, 10))):
            t = PC.model_iteration_cost("trident", ell, 784, B, kind, layers)
            a = PC.model_iteration_cost("aby3", ell, 784, B, kind, layers)
            assert t[3] < a[3], kind    # online bits
            assert t[2] <= a[2], kind   # online rounds


class TestTallyMechanics:
    def test_parallel_rounds_max(self):
        ctx = fresh()
        with ctx.tally.parallel():
            ctx.tally.add("a", "online", rounds=3, bits=10)
            ctx.tally.add("b", "online", rounds=5, bits=10)
        assert ctx.tally.online.rounds == 5
        assert ctx.tally.online.bits == 20

    def test_scaled_scope(self):
        ctx = fresh()
        with ctx.tally.scaled(12):
            ctx.tally.add("a", "online", rounds=1, bits=8)
        assert ctx.tally.online.rounds == 12
        assert ctx.tally.online.bits == 96
