"""Correctness of the 4PC protocols vs the plaintext oracle (paper III/IV).

Fixed-point products carry the paper's probabilistic 1-LSB truncation error
(2^-13 with frac=13); tolerances are a few LSBs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocols as PR
from repro.core import conversions as CV
from repro.core.context import make_context
from repro.core.ring import RING64, RING32
from repro.core.shares import AShare

LSB = 2.0 ** -13


def enc_share(ctx, x):
    return PR.share(ctx, ctx.ring.encode(x))


# ---------------------------------------------------------------------------
# Sharing semantics
# ---------------------------------------------------------------------------
class TestSharing:
    def test_share_reveal_roundtrip(self, ctx, rng):
        x = rng.randn(7, 3) * 10
        xs = enc_share(ctx, x)
        np.testing.assert_allclose(ctx.ring.decode(xs.reveal()), x,
                                   atol=LSB)

    @pytest.mark.parametrize("owner", [0, 1, 2, 3])
    def test_share_any_owner(self, ctx, rng, owner):
        x = rng.randn(5)
        xs = PR.share(ctx, ctx.ring.encode(x), owner=owner)
        np.testing.assert_allclose(ctx.ring.decode(xs.reveal()), x, atol=LSB)

    def test_shares_are_masked(self, ctx, rng):
        """m_v alone reveals nothing: it is uniformly random-looking, not v."""
        x = np.zeros(1000)
        xs = enc_share(ctx, x)
        m = np.asarray(xs.m)
        # if m leaked v it would be constant zero
        assert len(np.unique(m)) > 990

    def test_lambda_components_sum(self, ctx, rng):
        x = rng.randn(6)
        xs = enc_share(ctx, x)
        m = np.asarray(xs.m, np.uint64)
        lam = np.asarray(xs.lam_sum, np.uint64)
        v = (m - lam).astype(np.int64) / ctx.ring.scale
        np.testing.assert_allclose(v, x, atol=LSB)

    def test_ash_by_p0(self, ctx, rng):
        v = ctx.ring.encode(rng.randn(4, 4))
        sh = PR.ash_by_p0(ctx, v)
        assert sh.shape[0] == 3
        np.testing.assert_array_equal(
            np.asarray(sh[0] + sh[1] + sh[2]), np.asarray(v))


# ---------------------------------------------------------------------------
# Linear (local) gates
# ---------------------------------------------------------------------------
class TestLinearity:
    def test_add_sub_neg(self, ctx, rng):
        x, y = rng.randn(5), rng.randn(5)
        xs, ys = enc_share(ctx, x), enc_share(ctx, y)
        np.testing.assert_allclose(
            ctx.ring.decode((xs + ys).reveal()), x + y, atol=2 * LSB)
        np.testing.assert_allclose(
            ctx.ring.decode((xs - ys).reveal()), x - y, atol=2 * LSB)
        np.testing.assert_allclose(
            ctx.ring.decode((-xs).reveal()), -x, atol=LSB)

    def test_public_constant_add(self, ctx, rng):
        x = rng.randn(5)
        xs = enc_share(ctx, x)
        c = ctx.ring.encode(2.5)
        np.testing.assert_allclose(
            ctx.ring.decode((xs + c).reveal()), x + 2.5, atol=LSB)

    def test_public_int_mul(self, ctx, rng):
        x = rng.randn(5)
        xs = enc_share(ctx, x)
        np.testing.assert_allclose(
            ctx.ring.decode(xs.mul_public(7).reveal()), 7 * x, atol=7 * LSB)

    def test_linear_costs_zero(self, rng):
        c = make_context(RING64)
        xs, ys = enc_share(c, rng.randn(3)), enc_share(c, rng.randn(3))
        before = c.tally.totals()
        _ = xs + ys - xs.mul_public(3)
        assert c.tally.totals() == before  # local ops are free


# ---------------------------------------------------------------------------
# Multiplication family
# ---------------------------------------------------------------------------
class TestMult:
    def test_mult(self, ctx, rng):
        x, y = rng.randn(8) * 5, rng.randn(8) * 5
        z = PR.mult(ctx, enc_share(ctx, x), enc_share(ctx, y))
        # no truncation: result carries 2f fractional bits
        got = np.asarray(ctx.ring.to_signed(z.reveal()), np.int64) \
            / ctx.ring.scale ** 2
        np.testing.assert_allclose(got, x * y, atol=2e-3)

    def test_mult_tr(self, ctx, rng):
        x, y = rng.randn(100) * 8, rng.randn(100) * 8
        z = PR.mult_tr(ctx, enc_share(ctx, x), enc_share(ctx, y))
        np.testing.assert_allclose(ctx.ring.decode(z.reveal()), x * y,
                                   atol=1e-2)

    def test_dotp(self, ctx, rng):
        x, y = rng.randn(4, 64), rng.randn(4, 64)
        z = PR.dotp(ctx, enc_share(ctx, x), enc_share(ctx, y))
        got = np.asarray(ctx.ring.to_signed(z.reveal()), np.int64) \
            / ctx.ring.scale ** 2
        np.testing.assert_allclose(got, np.sum(x * y, -1), atol=1e-2)

    def test_matmul_tr(self, ctx, rng):
        a, b = rng.randn(9, 17), rng.randn(17, 5)
        z = PR.matmul_tr(ctx, enc_share(ctx, a), enc_share(ctx, b))
        np.testing.assert_allclose(ctx.ring.decode(z.reveal()), a @ b,
                                   atol=2e-2)

    def test_batched_matmul_tr(self, ctx, rng):
        a, b = rng.randn(3, 6, 7), rng.randn(3, 7, 4)
        z = PR.matmul_tr(ctx, enc_share(ctx, a), enc_share(ctx, b))
        np.testing.assert_allclose(ctx.ring.decode(z.reveal()),
                                   a @ b, atol=2e-2)

    def test_truncation_lsb_error_bound(self, ctx, rng):
        """Pi_MultTr's error is +-1 LSB with high probability (paper V-A)."""
        x = rng.randn(4096)
        y = rng.randn(4096)
        z = PR.mult_tr(ctx, enc_share(ctx, x), enc_share(ctx, y))
        err = np.abs(ctx.ring.decode(z.reveal()) - x * y)
        # encoding error of x,y contributes ~|x|+|y| LSBs; few-LSB bound
        assert np.quantile(err, 0.999) < 16 * LSB

    def test_collapse_mode_equivalent(self, rng):
        """Component-collapsed evaluation computes the same product (PRF
        streams differ because collapse skips Pi_Zero draws, so the +-1 LSB
        truncation noise may differ; values agree to 2 LSB)."""
        a, b = rng.randn(5, 6), rng.randn(6, 4)
        c1 = make_context(RING64, seed=3)
        c2 = make_context(RING64, seed=3, collapse=True)
        z1 = PR.matmul_tr(c1, enc_share(c1, a), enc_share(c1, b))
        z2 = PR.matmul_tr(c2, enc_share(c2, a), enc_share(c2, b))
        np.testing.assert_allclose(
            np.asarray(c1.ring.decode(z1.reveal())),
            np.asarray(c2.ring.decode(z2.reveal())), atol=4 * LSB)

    def test_collapse_mode_same_cost(self, rng):
        """collapse is an HLO-flop optimization only: tallies identical."""
        a, b = rng.randn(5, 6), rng.randn(6, 4)
        c1 = make_context(RING64, seed=3)
        c2 = make_context(RING64, seed=3, collapse=True)
        PR.matmul_tr(c1, enc_share(c1, a), enc_share(c1, b))
        PR.matmul_tr(c2, enc_share(c2, a), enc_share(c2, b))
        assert c1.tally.totals() == c2.tally.totals()

    def test_standalone_truncation(self, ctx, rng):
        x = rng.randn(32) * 3
        xs = enc_share(ctx, x)
        prod = PR.mult(ctx, xs, enc_share(ctx, np.ones(32)))
        t = PR.truncate_share(ctx, prod)
        np.testing.assert_allclose(ctx.ring.decode(t.reveal()), x, atol=1e-2)


# ---------------------------------------------------------------------------
# Offline/online twin-trace split (the paradigm itself)
# ---------------------------------------------------------------------------
class TestOfflineOnline:
    def test_split_matches_fused(self, rng):
        a, b = rng.randn(4, 8), rng.randn(8, 2)

        def program(ctx):
            xs = PR.share(ctx, ctx.ring.encode(a))
            ys = PR.share(ctx, ctx.ring.encode(b))
            z = PR.matmul_tr(ctx, xs, ys)
            return PR.mult_tr(ctx, z, z)

        fused = make_context(RING64, seed=11)
        want = fused.ring.decode(program(fused).reveal())

        off = make_context(RING64, seed=11, mode="offline")
        program(off)                      # records materials
        on = make_context(RING64, seed=11, mode="online")
        on.materials = off.materials      # ship preprocessing
        got = on.ring.decode(program(on).reveal())
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_online_phase_p0_free(self, rng):
        """online comm of Pi_Mult involves only P1-P3 (3 elements)."""
        c = make_context(RING64)
        xs = enc_share(c, rng.randn(1))
        ys = enc_share(c, rng.randn(1))
        base = c.tally.online.bits
        PR.mult(c, xs, ys)
        assert c.tally.online.bits - base == 3 * 64


# ---------------------------------------------------------------------------
# Malicious-security abort semantics
# ---------------------------------------------------------------------------
class TestMalicious:
    def test_no_tamper_no_abort(self, ctx, rng):
        z = PR.mult_tr(ctx, enc_share(ctx, rng.randn(3)),
                       enc_share(ctx, rng.randn(3)))
        _ = z.reveal()
        assert not bool(ctx.abort_flag())

    def test_tamper_aborts(self, rng):
        """Flipping one consistency-check operand sets the abort flag --
        the Fig. 5 fair-reconstruction path."""
        c = make_context(RING64)
        good = c.ring.encode(rng.randn(4))
        bad = good + jnp.asarray(1, c.ring.dtype)
        c.check_equal(good, bad, "tamper")
        assert bool(c.abort_flag())

    def test_checks_accumulate(self, ctx, rng):
        PR.mult(ctx, enc_share(ctx, rng.randn(2)),
                enc_share(ctx, rng.randn(2)))
        assert len(ctx.checks) > 0


# ---------------------------------------------------------------------------
# 32-bit ring
# ---------------------------------------------------------------------------
class TestRing32:
    def test_mult_tr_ring32(self, ctx32, rng):
        """Guarded r sampling (protocols.TRUNC_GUARD) keeps the opened
        z - r from wrapping mod 2^32, so the Fig. 18 truncation error stays
        at the 1-LSB probabilistic level even at ell=32, frac=13."""
        x, y = rng.randn(50), rng.randn(50)
        z = PR.mult_tr(ctx32, PR.share(ctx32, ctx32.ring.encode(x)),
                       PR.share(ctx32, ctx32.ring.encode(y)))
        np.testing.assert_allclose(ctx32.ring.decode(z.reveal()), x * y,
                                   atol=1e-2)

    def test_wraparound_semantics(self, ctx32):
        big = np.asarray([2.0 ** 17], np.float64)
        xs = PR.share(ctx32, ctx32.ring.encode(big))
        z = PR.mult_tr(ctx32, xs, xs)   # 2^34 * 2^13 >> 2^31: wraps
        v = ctx32.ring.decode(z.reveal())
        assert np.all(np.isfinite(v))   # wraps silently, never NaN
