"""Live prep streaming into running party daemons.

The acceptance contract of the live subsystem:

  * a 4-process ``ClusterSGD`` run whose PrepBank starts EMPTY trains N
    steps bit-identically to the joint simulation, with ZERO offline
    bytes on the party mesh (transport-enforced) and all prep arriving
    via the control channel while earlier steps run online;
  * dealer death mid-stream fails the blocked training step loudly with
    the dealer's traceback (not a generic timeout), and replaying a
    streamed session raises ``PrepReplayError`` with session/step
    attribution;
  * a failed task POISONS the cluster: later submits raise
    ``ClusterPoisoned`` immediately instead of hanging until timeout;
  * ``PrepBank`` frees consumed sessions (tombstones) so long runs have
    bounded residency, and seeking a live bank past the dealer's
    watermark names the watermark.

Cluster spawns are expensive (a JAX import per process), so the live
training run is module-scoped and shared across assertions.
"""
import functools
import threading
import time

import numpy as np
import pytest

from repro.offline import (ContinuousDealer, DealerDaemon, LivePrepBank,
                           PrepBank, PrepError, PrepMissingError,
                           PrepReplayError, PrepStore)
from repro.runtime.net.cluster import ClusterPoisoned, PartyCluster
from repro.train import data as D
from repro.train import secure_sgd as SGD

SEED = 17
STEPS = 3
BATCH = 8

_task = SGD.logreg_task(features=6, lr=0.5)
_data = D.RegressionData(features=6, n=256, seed=1, logistic=True)
_params0 = _task.init_params(seed=0)


def _joint_reference():
    p, out = dict(_params0), []
    for step in range(STEPS):
        p, loss, _ = SGD.run_step(_task, p, _data.batch(step, BATCH),
                                  step=step, base_seed=SEED, world="joint")
        out.append((dict(p), loss))
    return out


@pytest.fixture(scope="module")
def live_run():
    """One live cluster end to end: empty bank -> streamed training ->
    replay attempt -> poisoned submit.  Returns everything the tests
    assert on."""
    out = {"steps": []}
    with PartyCluster(live_prep=True, timeout=120) as cluster:
        with SGD.attach_live_dealer(cluster, _task, _params0,
                                    _data.batch(0, BATCH), base_seed=SEED,
                                    ahead=2, total=STEPS) as dealer:
            sgd = SGD.ClusterSGD(cluster, _task, base_seed=SEED,
                                 prep="live")
            p = dict(_params0)
            for step in range(STEPS):
                p, loss, abort = sgd.step_fn(p, step,
                                             *_data.batch(step, BATCH))
                out["steps"].append((dict(p), loss, abort))
            out["offline_bits_on_mesh"] = sgd.offline_bits_on_mesh()
            out["results"] = sgd.results
            out["dealer_dealt"] = dealer.dealt

            # a retried (replayed) streamed step must fail loudly with
            # session/step attribution...
            with pytest.raises(RuntimeError) as replay:
                sgd.step_fn(p, 1, *_data.batch(1, BATCH))
            out["replay_msg"] = str(replay.value)

            # ...which poisons the cluster: the NEXT submit raises a
            # named error immediately, not after the full timeout
            t0 = time.monotonic()
            with pytest.raises(ClusterPoisoned) as poisoned:
                sgd.step_fn(p, 2, *_data.batch(2, BATCH))
            out["poisoned_s"] = time.monotonic() - t0
            out["poisoned_msg"] = str(poisoned.value)
    return out


class TestLiveStreamedTraining:
    def test_empty_bank_trains_bit_identical_to_joint(self, live_run):
        """The acceptance criterion: the bank starts empty, every step's
        material arrives over the control channel, and the (params, loss)
        trajectory is bit-identical to the joint simulation."""
        ref = _joint_reference()
        for step, (p, loss, abort) in enumerate(live_run["steps"]):
            assert not abort
            assert loss == ref[step][1], step
            for k in p:
                assert np.array_equal(p[k], ref[step][0][k]), (step, k)
        assert live_run["dealer_dealt"] == STEPS

    def test_zero_offline_bytes_on_mesh(self, live_run):
        """All prep crossed the control channel; the TCP mesh carried
        ZERO offline bits (transport-enforced during each task)."""
        assert live_run["offline_bits_on_mesh"] == 0
        for results in live_run["results"]:
            for r in results:
                assert r.totals["offline"]["bits"] == 0, f"P{r.rank}"
                assert r.totals["online"]["bits"] > 0, f"P{r.rank}"

    def test_replay_of_streamed_session_names_session_and_party(
            self, live_run):
        msg = live_run["replay_msg"]
        assert "already consumed" in msg
        assert "session 1" in msg          # which session was replayed
        assert "step 1" in msg             # streamed stores carry step meta

    def test_failed_task_poisons_cluster(self, live_run):
        """The satellite bugfix: after a task failure the next submit
        raises ClusterPoisoned immediately (the daemons already exited),
        instead of hanging until the full timeout."""
        assert live_run["poisoned_s"] < 5.0, live_run["poisoned_s"]
        assert "already consumed" in live_run["poisoned_msg"]


# ---------------------------------------------------------------------------
# Dealer death mid-stream: loud, attributed failure (its own cluster).
# ---------------------------------------------------------------------------
def _boom_program(rt):
    raise ValueError("boom: dealer died mid-stream")


def _flaky_factory(step, *, task, params, batch):
    """Deals step 0 fine, explodes on step 1 -- the dealer's death
    happens while the cluster is mid-training."""
    if step >= 1:
        return _boom_program
    return functools.partial(SGD._live_deal_program, task=task,
                             params=params, batch=batch)


class TestDealerDeathMidStream:
    def test_blocked_step_fails_with_dealer_traceback(self):
        zp, zb = SGD.zero_inputs(_task, _params0, _data.batch(0, BATCH))
        with PartyCluster(live_prep=True, timeout=60) as cluster:
            with DealerDaemon(
                    cluster,
                    functools.partial(_flaky_factory, task=_task,
                                      params=zp, batch=zb),
                    ring=cluster.ring, base_seed=SEED, ahead=2,
                    total=STEPS) as dealer:
                sgd = SGD.ClusterSGD(cluster, _task, base_seed=SEED,
                                     prep="live")
                p, loss, abort = sgd.step_fn(dict(_params0), 0,
                                             *_data.batch(0, BATCH))
                assert not abort           # step 0's session streamed fine

                t0 = time.monotonic()
                with pytest.raises(RuntimeError) as ei:
                    sgd.step_fn(p, 1, *_data.batch(1, BATCH))
                took = time.monotonic() - t0
                msg = str(ei.value)
                # the DEALER's traceback, not a generic transport timeout
                assert "boom: dealer died mid-stream" in msg
                assert "will never arrive" in msg
                assert took < 30.0, f"{took}s -- smells like a timeout"
                assert dealer.failed is not None
                # and the cluster is poisoned for good measure
                with pytest.raises(ClusterPoisoned):
                    sgd.step_fn(p, 2, *_data.batch(2, BATCH))


# ---------------------------------------------------------------------------
# Live serving: batch k's session streams while batch k-1 is served.
# ---------------------------------------------------------------------------
_W = np.random.RandomState(0).randn(4, 3) * 0.4


def _serve_predict(rt, Xb):
    from repro.core.ring import RING64
    from repro.runtime import activations as RA
    from repro.runtime import protocols as RT
    xs = RT.share(rt, RING64.encode(Xb))
    w = RT.share(rt, RING64.encode(_W))
    out = RA.relu(rt, RT.matmul_tr(rt, xs, w))
    return RING64.decode(RT.reconstruct(rt, out)[1])


class TestServeLive:
    def test_query_stream_served_with_streamed_prep(self):
        from repro.serve.party_server import serve_over_sockets
        queries = np.random.RandomState(1).randn(6, 4)
        preds, report = serve_over_sockets(_serve_predict, queries,
                                           batch_size=4, seed=3,
                                           timeout=120, prep="live")
        assert len(preds) == len(queries)
        assert report["batches"] == 2 and not report["aborted"]
        assert report["online_only"] and report["prep"] == "live"
        assert report["totals"]["offline"]["bits"] == 0  # streamed, not sent
        assert report["live_sessions_streamed"] == 2
        ref = np.maximum(queries @ _W, 0.0)
        got = np.stack([np.asarray(p) for p in preds])
        assert np.abs(got - ref).max() < 0.02


# ---------------------------------------------------------------------------
# LivePrepBank semantics (no process spawns).
# ---------------------------------------------------------------------------
class TestLivePrepBank:
    def _store(self, step):
        s = PrepStore(meta={"step": step})
        s.put("mult#0", "mult", [{"lam": np.zeros(2)}] * 4)
        return s

    def test_seek_past_watermark_names_watermark(self):
        bank = LivePrepBank(ahead=2)
        bank.append(0, self._store(0))
        with pytest.raises(PrepMissingError) as ei:
            bank.seek(2)
        msg = str(ei.value)
        assert "not dealt yet" in msg
        assert "dealer watermark at 1" in msg

    def test_append_blocks_at_bounded_lookahead(self):
        bank = LivePrepBank(ahead=2)
        bank.append(0, self._store(0))
        bank.append(1, self._store(1))
        done = threading.Event()

        def feeder():
            bank.append(2, self._store(2))   # window full: must block
            done.set()

        t = threading.Thread(target=feeder, daemon=True)
        t.start()
        assert not done.wait(timeout=0.5), "append ignored the look-ahead"
        bank.next()                          # consume one -> room opens
        assert done.wait(timeout=10.0)
        t.join(timeout=10.0)
        assert bank.watermark == 3

    def test_out_of_order_append_rejected(self):
        bank = LivePrepBank(ahead=4)
        with pytest.raises(PrepError, match="out of order"):
            bank.append(3, self._store(3))

    def test_wait_for_raises_dealer_failure_not_timeout(self):
        bank = LivePrepBank(ahead=2)
        bank.fail("TracebackFromTheDealer: kaboom")
        t0 = time.monotonic()
        with pytest.raises(PrepError, match="kaboom"):
            bank.wait_for(0, timeout=60.0)
        assert time.monotonic() - t0 < 5.0

    def test_wait_for_after_clean_finish_is_named(self):
        bank = LivePrepBank(ahead=2)
        bank.append(0, self._store(0))
        bank.finish(1)
        with pytest.raises(PrepMissingError, match="finished after 1"):
            bank.wait_for(1, timeout=60.0)


# ---------------------------------------------------------------------------
# PrepBank bounded residency (the memory-leak satellite).
# ---------------------------------------------------------------------------
def _tiny_program(rt):
    from repro.core.ring import RING64
    from repro.runtime import protocols as RT
    xs = RT.share(rt, RING64.encode(np.ones(3)))
    RT.mult_tr(rt, xs, xs)


class TestBoundedResidency:
    def test_consumed_sessions_are_tombstoned(self):
        bank = PrepBank()
        for k in range(8):
            s = PrepStore(meta={"session": k})
            s.put("t#0", "mult", [{"lam": np.zeros(4)}] * 4)
            bank.add(s)
        for _ in range(6):
            bank.next()
        assert len(bank) == 8 and bank.sessions_left == 2
        assert bank.resident() == 2        # consumed stores were freed
        with pytest.raises(PrepReplayError, match="already consumed"):
            bank.seek(3)                   # attribution survives freeing

    def test_forward_seek_frees_skipped_sessions(self):
        bank = PrepBank()
        for k in range(5):
            s = PrepStore(meta={"session": k})
            s.put("t#0", "mult", [{"lam": np.zeros(4)}] * 4)
            bank.add(s)
        bank.seek(4)                       # skip 0..3: never reachable again
        assert bank.resident() == 1

    def test_long_continuous_run_has_bounded_residency(self):
        """A ContinuousDealer-driven run of many steps keeps at most
        ~ahead live stores in the bank at any point -- the long-training
        memory contract."""
        ahead, steps = 2, 12
        peak = 0
        with ContinuousDealer(lambda s: _tiny_program, base_seed=0,
                              ahead=ahead, total=steps) as dealer:
            for _ in range(steps):
                dealer.next_store(timeout=60.0)
                peak = max(peak, dealer.bank.resident())
        assert len(dealer.bank) == steps
        # resident never exceeds the look-ahead window (+1 for the store
        # dealt between consumption and the residency probe)
        assert peak <= ahead + 1, peak

    def test_partially_consumed_bank_refuses_save(self, tmp_path):
        bank = PrepBank()
        s = PrepStore(meta={"session": 0})
        s.put("t#0", "mult", [{"lam": np.zeros(4)}] * 4)
        bank.add(s)
        bank.next()
        with pytest.raises(PrepError, match="consumed"):
            bank.save(str(tmp_path / "bank"))
