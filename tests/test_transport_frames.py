"""RoundFrames parallel/branch bookkeeping edge cases (runtime/transport.py).

The frames are the transport-side twin of CostTally's round algebra:
parallel scopes take the max over their branches, branch scopes sequence
(sum), and amounts route to the nearest enclosing frame capturing their
phase.  These invariants were previously only exercised indirectly
through whole protocols; here they are pinned directly.
"""
import pytest

from repro.runtime.transport import PHASES, LocalTransport, RoundFrames


def test_flat_adds_accumulate():
    fr = RoundFrames()
    fr.add("online", 1)
    fr.add("online", 2)
    fr.add("offline", 5)
    assert fr.total == {"offline": 5, "online": 3}


def test_parallel_keeps_max_of_branches():
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            fr.add("online", 3)
        with fr.branch():
            fr.add("online", 1)
    assert fr.total["online"] == 3


def test_branch_sequences_inside_itself():
    # one branch doing two sequential rounds counts both
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            fr.add("online", 1)
            fr.add("online", 1)
        with fr.branch():
            fr.add("online", 1)
    assert fr.total["online"] == 2


def test_nested_branch_inside_parallel_inside_branch():
    # branch { 2 rounds } || branch { parallel { 3 || 1 } } -> max(2, 3)
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            fr.add("online", 2)
        with fr.branch():
            with fr.parallel():
                with fr.branch():
                    fr.add("online", 3)
                with fr.branch():
                    fr.add("online", 1)
    assert fr.total["online"] == 3


def test_sequential_parallels_sum():
    fr = RoundFrames()
    for amount in (2, 3):
        with fr.parallel():
            with fr.branch():
                fr.add("online", amount)
    assert fr.total["online"] == 5


def test_empty_frames_contribute_nothing():
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            pass
        with fr.branch():
            pass
    with fr.branch():
        pass
    assert fr.total == {p: 0 for p in PHASES}


def test_zero_amounts_do_not_fold_out():
    # fold-out skips zero cells: an explicit add(phase, 0) must leave the
    # totals untouched (a round scope that moved nothing counts nothing)
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            fr.add("online", 0)
    assert fr.total["online"] == 0


def test_phase_filtered_parallel_bypasses_other_phase():
    # parallel(phases=("online",)): offline adds skip the frame entirely
    # and land on the totals (sequential), while online adds max-merge --
    # exactly how offline prep traffic behaves inside an online-overlap
    # scope
    fr = RoundFrames()
    with fr.parallel(phases=("online",)):
        with fr.branch():
            fr.add("online", 2)
            fr.add("offline", 4)
        with fr.branch():
            fr.add("online", 1)
            fr.add("offline", 4)
    assert fr.total["online"] == 2
    assert fr.total["offline"] == 8


def test_fold_out_ordering_inner_before_outer():
    # the inner parallel folds its max into the enclosing branch BEFORE
    # the outer parallel compares branches: [para{4||1}; 1] || [3] ->
    # max(4+1, 3) = 5, not max(4, 1, 1, 3)
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            with fr.parallel():
                with fr.branch():
                    fr.add("online", 4)
                with fr.branch():
                    fr.add("online", 1)
            fr.add("online", 1)
        with fr.branch():
            fr.add("online", 3)
    assert fr.total["online"] == 5


def test_add_outside_any_frame_during_stack_unwound():
    # after scopes exit, the stack is empty again: later adds are flat
    fr = RoundFrames()
    with fr.parallel():
        with fr.branch():
            fr.add("online", 7)
    fr.add("online", 1)
    assert fr.total["online"] == 8


def test_transport_round_uses_frames():
    # a transport-level sanity pin: two parallel branches each moving one
    # round overlap to ONE counted round, and bits always sum
    tp = LocalTransport()
    import numpy as np
    payload = np.zeros(4, dtype=np.uint64)
    with tp.parallel():
        with tp.branch():
            with tp.round("online"):
                tp.send(0, 1, payload, tag="a", nbits=64, phase="online")
        with tp.branch():
            with tp.round("online"):
                tp.send(2, 3, payload, tag="b", nbits=64, phase="online")
    assert tp.rounds["online"] == 1
    assert tp.bits("online") == 2 * 4 * 64


def test_empty_round_scope_counts_zero_rounds():
    tp = LocalTransport()
    with tp.round("online"):
        pass
    assert tp.rounds["online"] == 0


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-x", "-q"]))
