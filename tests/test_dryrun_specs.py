"""Dry-run plumbing: abstract param specs == real init (smoke configs),
sharding divisibility fitting, roofline parsing.

The 512-device lower+compile sweep itself runs via launch/dryrun.py (it
must own the process to set XLA_FLAGS before jax init); here we validate
every pure piece of it in-process.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs as CFGS
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.launch import specs as SP
from repro.launch import roofline as RL
from repro.nn.engine import TridentEngine, PlainEngine
from repro.nn import model as M


def tree_shapes(tree):
    return jax.tree_util.tree_map(
        lambda x: tuple(x.shape), tree,
        is_leaf=lambda x: hasattr(x, "shape"))


@pytest.mark.parametrize("arch", CFGS.ARCHS)
def test_param_specs_match_real_init(arch):
    """Abstract specs must agree with the real init's structure+shapes."""
    cfg = CFGS.get(arch).SMOKE
    params_np = M.init_params(cfg, seed=0)
    eng = TridentEngine(make_context(seed=0))
    real = M.params_to_engine(eng, params_np)
    spec = SP.param_specs(cfg, RING64, trident=True)
    real_s = jax.tree_util.tree_structure(real)
    spec_s = jax.tree_util.tree_structure(spec)
    assert real_s == spec_s, (arch, real_s, spec_s)
    for (pa, a), (_pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(real),
            jax.tree_util.tree_leaves_with_path(spec)):
        assert tuple(a.shape) == tuple(b.shape), (arch, pa, a.shape, b.shape)
        assert a.dtype == b.dtype


@pytest.mark.parametrize("arch", ["xlstm_350m", "whisper_tiny"])
def test_decode_cache_specs_match_prefill(arch):
    """Cache SDS layout == what serve_prefill actually emits."""
    cfg = CFGS.get(arch).SMOKE
    params_np = M.init_params(cfg, seed=0)
    eng = TridentEngine(make_context(seed=0, collapse=True))
    params = M.params_to_engine(eng, params_np)
    rng = np.random.RandomState(0)
    B, S = 2, 8
    ids = rng.randint(0, cfg.vocab, (B, S))
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = eng.from_plain(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model))
    _, caches = M.serve_prefill(eng, cfg, params, ids, **kw)
    spec = SP.decode_cache_specs(cfg, B, S, trident=True)
    got_s = jax.tree_util.tree_structure(caches)
    want_s = jax.tree_util.tree_structure(spec)
    assert got_s == want_s, (arch, got_s, want_s)
    for (pa, a), (_pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(caches),
            jax.tree_util.tree_leaves_with_path(spec)):
        assert tuple(a.shape) == tuple(b.shape), (arch, pa, a.shape, b.shape)


def test_fit_sharding_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = SP.fit_sharding(mesh, (4, 51865, 384), P(None, "model", None))
    assert s.spec == P(None, "model", None)   # 1-way always divides
    mesh16 = None
    # simulate a 16-way axis via a fake mesh-shape mapping
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        def __init__(self, real):
            self._real = real
    # use the real helper's arithmetic directly
    from jax.sharding import PartitionSpec
    entries = SP.fit_sharding.__wrapped__ if hasattr(
        SP.fit_sharding, "__wrapped__") else None
    # arithmetic check: 51865 % 16 != 0 -> dropped
    assert 51865 % 16 != 0 and 151936 % 16 == 0


def test_roofline_collective_parse():
    """collective_bytes parses HLO-ish text correctly."""
    class FakeCompiled:
        def as_text(self):
            return """
  %ag = u64[4,128,256]{2,1,0} all-gather(u64[4,8,256] %x), dims={1}
  %ar = f32[1024]{0} all-reduce(f32[1024] %y), to_apply=%add
  %rs = u64[2,64]{1,0} reduce-scatter(u64[2,1024] %z), dimensions={1}
  %cp = u32[16]{0} collective-permute(u32[16] %w)
"""
    got = RL.collective_bytes(FakeCompiled())
    # operand bytes only
    want = (4 * 8 * 256 * 8) + (1024 * 4) + (2 * 1024 * 8) + (16 * 4)
    assert got == want, (got, want)


def test_roofline_terms_bottleneck():
    class Cfg:
        d_model, d_ff, vocab, n_layers = 1024, 4096, 32000, 16
        n_heads, n_kv_heads, dh = 16, 16, 64
        n_experts, top_k, act, family = 0, 0, "swiglu", "dense"
    m = {"devices": 256, "flops": 1e15, "bytes_accessed": 1e12,
         "collective_bytes": 1e10}
    t = RL.roofline_terms(m, Cfg, 256, 4096, "train")
    assert t["t_compute"] == pytest.approx(1e15 / RL.PEAK_FLOPS)
    assert t["t_memory"] == pytest.approx(1e12 / RL.HBM_BW)
    assert t["t_collective"] == pytest.approx(1e10 / RL.LINK_BW)
    assert t["bottleneck"] in ("t_compute_limb", "t_memory", "t_collective")


def test_mesh_shapes():
    """Mesh factory maths (construction itself needs the 512-device env)."""
    from repro.launch.mesh import make_production_mesh
    import inspect
    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
