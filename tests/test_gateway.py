"""ServingGateway: cluster pools, dynamic batching, async dispatch.

Acceptance contract of the serving plane: queries coalesced into shared
batches across a POOL of PartyClusters come back bit-identical to the
joint simulation of the same (padded batch, seed); a killed pool member
is evicted mid-stream with its queued queries re-dispatched (nothing
dropped) and the eviction visible in ``health()``; the ``_free_ports``
TOCTOU race is survived by rebooting the mesh on fresh ports; and the
sharded data-parallel trainer reproduces the mean-of-shard-updates
trajectory exactly.

Cluster spawns are the expensive part, so each test boots the smallest
pool that proves its claim.
"""
import threading

import numpy as np
import pytest

from repro.core import activations as ACT
from repro.core import protocols as PR
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.runtime import activations as RA
from repro.runtime import protocols as RT

TIMEOUT = 120.0
_rng = np.random.RandomState(7)
W1 = _rng.randn(4, 3) * 0.4


def gw_predict(rt, Xb):
    """Module-level predict_fn (spawn pickling): share -> linear -> relu
    -> reconstruct, returning P1's opened copy."""
    xs = RT.share(rt, RING64.encode(Xb))
    w = RT.share(rt, RING64.encode(W1))
    out = RA.relu(rt, RT.matmul_tr(rt, xs, w))
    return RING64.decode(RT.reconstruct(rt, out)[1])


def joint_predict(Xb, seed):
    """The joint-simulation twin of ``gw_predict`` -- the bit-identity
    reference for a dispatched (padded batch, seed)."""
    ctx = make_context(RING64, seed=seed)
    xs = PR.share(ctx, RING64.encode(Xb))
    w = PR.share(ctx, RING64.encode(W1))
    out = ACT.relu(ctx, PR.matmul_tr(ctx, xs, w))
    return RING64.decode(np.asarray(PR.reconstruct(ctx, out)))


def trivial_program(rt, rank):
    """Tiny task for boot smokes."""
    xs = RT.share(rt, RING64.encode(np.ones((2, 2))))
    return RING64.decode(np.asarray(RT.reconstruct(rt, xs)[rank]))


def _check_against_joint(gw, futs, queries):
    """Every resolved query must equal the joint sim of the padded batch
    it was dispatched in, from the dispatch's seed (the LAST dispatch
    record naming the qid is the one that served it -- earlier records
    are evicted members' lost dispatches)."""
    # resolve everything FIRST: a dispatch record is appended before its
    # futures resolve, so after result() the serving record must exist
    got = [fut.result(timeout=TIMEOUT) for fut in futs]
    records = [rec for m in gw._members for rec in m.dispatch_log]
    for fut, out, q in zip(futs, got, queries):
        rec = [r for r in records if r["qids"] and fut.qid in r["qids"]][-1]
        ref = joint_predict(rec["X"], rec["seed"])
        i = rec["qids"].index(fut.qid)
        assert np.array_equal(out, ref[i]), f"query {fut.qid}"
        # and the reference row really is this query's prediction
        assert np.array_equal(rec["X"][i], np.asarray(q))


class TestDynamicBatching:
    def test_pool_batches_queries_bit_identical_to_joint_sim(self):
        from repro.serve.gateway import ServingGateway
        queries = np.random.RandomState(3).randn(12, 4)
        with ServingGateway(gw_predict, pool=2, max_batch=4,
                            max_wait_ms=100.0, base_seed=5,
                            timeout=TIMEOUT, keep_results=True) as gw:
            futs = [gw.submit(q) for q in queries]
            gw.drain(timeout=TIMEOUT)
            _check_against_joint(gw, futs, queries)
            rep = gw.report()
        assert rep["queries"] == 12
        assert rep["pool_size"] == 2 and rep["evictions"] == 0
        # the window really coalesced: fewer dispatches than queries
        assert rep["batches"] < 12 and rep["avg_batch_size"] > 1.0
        assert rep["p99_ms"] >= rep["p50_ms"] > 0.0

    def test_submits_from_many_threads(self):
        from repro.serve.gateway import ServingGateway
        queries = np.random.RandomState(5).randn(8, 4)
        futs = [None] * len(queries)
        with ServingGateway(gw_predict, pool=1, max_batch=4,
                            max_wait_ms=50.0, timeout=TIMEOUT,
                            keep_results=True) as gw:
            def feed(i):
                futs[i] = gw.submit(queries[i])
            threads = [threading.Thread(target=feed, args=(i,))
                       for i in range(len(queries))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            gw.drain(timeout=TIMEOUT)
            _check_against_joint(gw, futs, queries)


class TestEviction:
    def test_killed_member_evicted_queries_redispatched(self):
        from repro.serve.gateway import ServingGateway
        queries = np.random.RandomState(11).randn(8, 4)
        with ServingGateway(gw_predict, pool=2, max_batch=4,
                            max_wait_ms=None, timeout=TIMEOUT,
                            replace_evicted=False,
                            keep_results=True) as gw:
            victim = gw._members[0]
            # warm both members so the kill lands mid-stream
            warm = [gw.submit(q) for q in queries[:4]]
            gw.drain(timeout=TIMEOUT)
            for p in victim.backend.cluster._procs:
                p.kill()
            futs = [gw.submit(q) for q in queries[4:]]
            gw.flush()
            # every query resolves despite the dead member: lost batches
            # are re-dispatched to the survivor
            _check_against_joint(gw, warm + futs, queries)
            rep = gw.report()
            health = gw.health(timeout=5.0)
        assert rep["evictions"] >= 1 and rep["pool_size"] == 1
        assert rep["queries"] == 8
        assert health["healthy"] is False or health["pool"]  # doc present
        evicted = [mid for mid, h in health["pool"].items()
                   if h.get("evicted")]
        assert str(victim.idx) in evicted
        assert health["evictions"][0]["member"] == victim.idx

    def test_pool_exhausted_fails_futures_loudly(self):
        from repro.serve.gateway import ServingGateway
        with ServingGateway(gw_predict, pool=1, max_batch=2,
                            max_wait_ms=None, timeout=TIMEOUT,
                            replace_evicted=False) as gw:
            for p in gw._members[0].backend.cluster._procs:
                p.kill()
            futs = [gw.submit(q)
                    for q in np.random.RandomState(2).randn(2, 4)]
            gw.flush()
            for fut in futs:
                with pytest.raises(RuntimeError, match="pool exhausted"):
                    fut.result(timeout=TIMEOUT)


class TestPortRetry:
    def test_eaddrinuse_boot_retries_with_fresh_ports(self, monkeypatch):
        import socket as socket_mod

        from repro.runtime.net import cluster as cluster_mod

        # occupy a port, then serve it as rank 0's "free" port on the
        # first probe only -- the TOCTOU race, made deterministic
        blocker = socket_mod.socket(socket_mod.AF_INET,
                                    socket_mod.SOCK_STREAM)
        blocker.bind(("127.0.0.1", 0))
        blocker.listen(1)
        taken = blocker.getsockname()[1]
        real = cluster_mod._free_ports
        calls = {"n": 0}

        def racy(n):
            calls["n"] += 1
            ports = real(n)
            if calls["n"] == 1:
                return [taken] + ports[1:]
            return ports

        monkeypatch.setattr(cluster_mod, "_free_ports", racy)
        try:
            with cluster_mod.PartyCluster(timeout=TIMEOUT) as cluster:
                results = cluster.submit(trivial_program, timeout=TIMEOUT)
            assert calls["n"] >= 2          # first attempt lost the race
            assert all(np.array_equal(r.result, np.ones((2, 2)))
                       for r in results)
        finally:
            blocker.close()


class TestAsyncDispatch:
    def test_tasks_pipeline_on_one_cluster(self):
        from repro.runtime.net.cluster import PartyCluster
        with PartyCluster(timeout=TIMEOUT) as cluster:
            handles = [cluster.submit_nowait(trivial_program)
                       for _ in range(3)]
            assert cluster.inflight == 3
            out = [cluster.collect(h) for h in handles]
        assert cluster.inflight == 0
        for results in out:
            assert [r.rank for r in results] == [0, 1, 2, 3]
            assert all(np.array_equal(r.result, np.ones((2, 2)))
                       for r in results)
        assert cluster.tasks_run == 3 and len(cluster.task_walls) == 3


class TestShardedSGD:
    def test_sharded_trajectory_is_mean_of_shard_updates(self):
        from repro.runtime.net.cluster import PartyCluster
        from repro.train.secure_sgd import (ShardedClusterSGD, logreg_task,
                                            run_step, shard_batch)
        from repro.train import data as D
        task = logreg_task(features=4)
        params = task.init_params(seed=0)
        X, y = D.RegressionData(features=4, n=64, seed=9,
                                logistic=True).batch(0, 8)
        clusters = [PartyCluster(timeout=TIMEOUT) for _ in range(2)]
        try:
            sgd = ShardedClusterSGD(clusters, task, base_seed=21)
            p, cur = dict(params), dict(params)
            for step in range(2):
                cur, loss, abort = sgd.step_fn(cur, step, X, y)
                assert not abort
                # reference: the joint sim on each shard, then the mean
                news = []
                for shard in shard_batch((X, y), 2):
                    nw, _, _ = run_step(task, p, shard, step=step,
                                        base_seed=21, world="joint")
                    news.append(nw)
                ref = {k: np.mean([nw[k] for nw in news], axis=0)
                       for k in news[0]}
                for k in ref:
                    assert np.array_equal(cur[k], ref[k]), (step, k)
                p = dict(cur)
        finally:
            for c in clusters:
                c.close()

    def test_uneven_shards_rejected(self):
        from repro.train.secure_sgd import shard_batch
        with pytest.raises(ValueError, match="shard evenly"):
            shard_batch((np.zeros((7, 2)), np.zeros(7)), 2)


class TestServeMeterConsolidation:
    def test_in_process_server_counts_once_per_batch(self):
        from repro import obs
        from repro.serve.party_server import PartyPredictionServer

        def predict(rt, Xb):
            xs = RT.share(rt, RING64.encode(Xb))
            w = RT.share(rt, RING64.encode(W1))
            out = RA.relu(rt, RT.matmul_tr(rt, xs, w))
            return RING64.decode(RT.reconstruct(rt, out)[1])

        from repro.obs.registry import snapshot_total
        reg = obs.get_registry()
        q0 = snapshot_total(reg.snapshot(), "trident_serve_queries_total")
        b0 = snapshot_total(reg.snapshot(), "trident_serve_batches_total")
        srv = PartyPredictionServer(predict, batch_size=2, seed=3)
        for q in np.random.RandomState(1).randn(5, 4):
            srv.submit(q)
        preds = srv.flush()
        srv.close()
        assert len(preds) == 5
        rep = srv.report()
        assert rep["queries"] == 5 and rep["batches"] == 3
        assert not rep["aborted"]
        # exactly one registry increment per batch -- the gateway's
        # collector is the single implementation
        snap = reg.snapshot()
        assert snapshot_total(snap, "trident_serve_queries_total") - q0 == 5
        assert snapshot_total(snap, "trident_serve_batches_total") - b0 == 3
