"""SocketTransport: four OS processes over TCP vs the in-process backends.

The acceptance contract of the distributed transport subsystem: a full NN
secure inference (share -> linear layers with fused truncation -> ReLU /
sigmoid via the ported conversions -> reconstruct) produces bit-identical
outputs on LocalTransport, SocketTransport (four processes), and the joint
simulation, with identical measured byte/round accounting -- and a
tampered TCP message still flips the abort flag.

The cluster launches are the expensive part (a JAX import per process), so
the honest run is module-scoped and shared across assertions.
"""
import numpy as np
import pytest

from repro.core import activations as ACT
from repro.core import protocols as PR
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.runtime import FourPartyRuntime
from repro.runtime import activations as RA
from repro.runtime import protocols as RT
from repro.runtime.net import LAN, WAN, run_four_parties

SEED = 11
_rng = np.random.RandomState(0)
W1 = _rng.randn(4, 3) * 0.4
W2 = _rng.randn(3, 2) * 0.4
X = _rng.randn(2, 4)


def nn_program(rt, rank):
    """The acceptance-criteria NN: linear (fused trunc) -> relu -> linear
    -> sigmoid -> reconstruct.  Module-level so spawn can import it."""
    enc = RING64.encode
    xs = RT.share(rt, enc(X))
    w1 = RT.share(rt, enc(W1))
    w2 = RT.share(rt, enc(W2))
    h = RA.relu(rt, RT.matmul_tr(rt, xs, w1))
    out = RA.sigmoid(rt, RT.matmul_tr(rt, h, w2))
    opened = RT.reconstruct(rt, out)
    return np.asarray(opened[rank])


def local_reference():
    rt = FourPartyRuntime(RING64, seed=SEED)
    out = nn_program(rt, 1)
    return rt, out


@pytest.fixture(scope="module")
def socket_run():
    return run_four_parties(nn_program, seed=SEED, timeout=300,
                            net_model=WAN)


class TestSocketEqualsLocal:
    def test_bit_identical_across_three_backends(self, socket_run):
        rt, local_out = local_reference()
        # joint simulation (same program order as nn_program, so the PRF
        # counter streams line up exactly)
        ctx = make_context(RING64, seed=SEED)
        enc = RING64.encode
        xs, w1, w2 = (PR.share(ctx, enc(a)) for a in (X, W1, W2))
        h = ACT.relu(ctx, PR.matmul_tr(ctx, xs, w1))
        out = ACT.sigmoid(ctx, PR.matmul_tr(ctx, h, w2))
        joint_out = np.asarray(PR.reconstruct(ctx, out))
        assert np.array_equal(local_out, joint_out)
        for res in socket_run:
            assert np.array_equal(res.result, joint_out), f"P{res.rank}"
        assert rt.transport.totals() == ctx.tally.totals()

    def test_measured_traffic_matches_local(self, socket_run):
        rt, _ = local_reference()
        want_totals = rt.transport.totals()
        want_links = rt.transport.per_link()
        for res in socket_run:
            assert res.totals == want_totals, f"P{res.rank}"
            assert res.per_link == want_links, f"P{res.rank}"

    def test_honest_run_does_not_abort(self, socket_run):
        assert not any(res.abort for res in socket_run)

    def test_wan_model_reports_round_dominated_time(self, socket_run):
        """The WAN network model over the socket backend: modeled online
        time is dominated by the rtt term, as the paper predicts."""
        res = socket_run[0]
        assert res.modeled_s is not None
        rounds = res.totals["online"]["rounds"]
        bits = res.totals["online"]["bits"]
        rtt_term = rounds * WAN.default.rtt_s
        bw_term = bits / WAN.default.bandwidth_bps
        assert res.modeled_s["online"] >= rtt_term > 10 * bw_term
        # and the LAN preset would be bandwidth-cheap in absolute terms
        assert LAN.seconds_for(rounds, bits) < 0.1 * res.modeled_s["online"]


class TestBatchedFraming:
    def test_round_coalescing_bounds_frames(self, socket_run):
        """All messages a (link, round) carries ride one frame: a party's
        frame count is bounded by links x rounds, far below its message
        count (jmp payloads + hash copies + per-piece sends)."""
        res = socket_run[0]
        frames = sum(res.frames_sent.values())
        rounds = res.totals["offline"]["rounds"] \
            + res.totals["online"]["rounds"]
        assert frames > 0
        # <= one frame per link per round (+ slack for flush-on-recv
        # splitting a round's sends around a blocking receive)
        assert frames <= 3 * rounds + 3, (frames, rounds)

    def test_byte_accounting_unchanged_by_coalescing(self, socket_run):
        """Framing is transport metadata: per-tag bit accounting must be
        identical to the unbatched LocalTransport."""
        rt, _ = local_reference()
        assert socket_run[0].per_link == rt.transport.per_link()


class TestClusterReuse:
    def test_long_lived_daemons_serve_multiple_tasks(self):
        """One mesh, two submitted programs: per-task deltas agree with a
        fresh one-shot run (the ROADMAP's long-lived party daemons)."""
        from repro.runtime.net import PartyCluster
        with PartyCluster(ring=RING64, timeout=300) as cluster:
            a = cluster.submit(nn_program, seed=SEED)
            b = cluster.submit(nn_program, seed=SEED)
            assert cluster.tasks_run == 2
        rt, local_out = local_reference()
        for res in (a[1], b[1]):
            assert np.array_equal(res.result, local_out)
        assert a[0].totals == b[0].totals == rt.transport.totals()


class TestSocketFaultInjection:
    def test_tampered_tcp_message_aborts(self):
        """Corrupt one gamma piece on P0's outgoing wire: the receiving
        process's hash cross-check must flip its abort flag."""
        res = run_four_parties(
            nn_program, seed=SEED, timeout=300,
            tampers=[{"src": 0, "tag": ".g2", "delta": 5}])
        assert any(r.abort for r in res)


def serve_predict(rt, Xb):
    """Module-level predict_fn for serve_over_sockets (spawn pickling)."""
    xs = RT.share(rt, RING64.encode(Xb))
    w = RT.share(rt, RING64.encode(W1))
    out = RA.relu(rt, RT.matmul_tr(rt, xs, w))
    return RING64.decode(RT.reconstruct(rt, out)[1])


class TestServeOverSockets:
    def test_query_stream_served_across_processes(self):
        from repro.serve.party_server import serve_over_sockets
        queries = np.random.RandomState(1).randn(6, 4)
        preds, report = serve_over_sockets(serve_predict, queries,
                                           batch_size=4, seed=3,
                                           timeout=300)
        assert len(preds) == len(queries)
        assert report["batches"] == 2 and not report["aborted"]
        ref = np.maximum(queries @ W1, 0.0)
        got = np.stack([np.asarray(p) for p in preds])
        assert np.abs(got - ref).max() < 0.02
