"""RuntimeEngine + secure SGD on the party runtime.

The acceptance contract of the RuntimeEngine refactor:

  * the NR reciprocal / rsqrt normalization is ported to the party
    runtime bit-identically, with measured wire == analytic CostTally;
  * ``paper_ml`` training steps produce bit-identical (params, loss)
    trajectories on TridentEngine (joint sim), RuntimeEngine over
    LocalTransport, and RuntimeEngine on the 4-process socket cluster,
    from the same step-indexed seeds;
  * per-step prep: training steps run online-only from dealt stores with
    ZERO offline bytes on the wire (transport-enforced), the
    ContinuousDealer refills a PrepBank across steps, and
    checkpoint/restore replays a step with the same prep tags and
    bit-identical outputs;
  * prep errors (replay / missing / kind) name the tag, kind, and party.
"""
import numpy as np
import pytest

from repro.core import activations as ACT
from repro.core import protocols as PR
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.nn.engine import PlainEngine, TridentEngine
from repro.nn.runtime_engine import RuntimeEngine
from repro.offline import (ContinuousDealer, PrepError, PrepKindError,
                           PrepMissingError, PrepReplayError, PrepStore,
                           deal, run_online)
from repro.runtime import FourPartyRuntime
from repro.runtime import activations as RA
from repro.runtime import protocols as RT
from repro.train import data as D
from repro.train import paper_ml as PML
from repro.train import secure_sgd as SGD
from repro.train.trainer import Trainer, TrainerConfig, seed_for_step

SEED = 11


def enc(x):
    return RING64.encode(np.asarray(x))


# ---------------------------------------------------------------------------
# The ported NR normalization: bit-identity + measured wire == tally.
# ---------------------------------------------------------------------------
class TestRuntimeNR:
    VALS = np.asarray([0.7, 3.2, 11.0, 0.05])

    @pytest.mark.parametrize("op", ["reciprocal", "rsqrt"])
    def test_bit_identical_and_measured(self, op):
        ctx = make_context(seed=SEED)
        x = PR.share(ctx, enc(self.VALS))
        want = getattr(ACT, op)(ctx, x)
        rt = FourPartyRuntime(RING64, seed=SEED)
        xs = RT.share(rt, enc(self.VALS))
        got = getattr(RA, op)(rt, xs)
        assert bool((got.to_joint().data == want.data).all())
        assert rt.transport.totals() == ctx.tally.totals()
        assert not bool(rt.abort_flag())
        # and the value is actually a reciprocal / rsqrt
        ref = 1.0 / self.VALS if op == "reciprocal" \
            else 1.0 / np.sqrt(self.VALS)
        np.testing.assert_allclose(RING64.decode(want.reveal()), ref,
                                   rtol=0.02)

    def test_smx_softmax_matches_joint(self):
        vals = np.asarray([[0.5, -1.0, 2.0], [1.5, 0.25, -0.75]])
        ctx = make_context(seed=3)
        want = ACT.smx_softmax(ctx, PR.share(ctx, enc(vals)))
        rt = FourPartyRuntime(RING64, seed=3)
        got = RA.smx_softmax(rt, RT.share(rt, enc(vals)))
        assert bool((got.to_joint().data == want.data).all())
        assert rt.transport.totals() == ctx.tally.totals()


# ---------------------------------------------------------------------------
# The shared Engine surface on the runtime world.
# ---------------------------------------------------------------------------
class TestRuntimeEngineSurface:
    def test_shape_and_public_ops_match_plain(self):
        rng = np.random.RandomState(0)
        x = rng.randn(4, 6)
        pe = PlainEngine()
        re = RuntimeEngine(FourPartyRuntime(RING64, seed=5))
        xs = re.from_plain(x)

        def close(got, want):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-3)

        close(re.to_plain(re.reshape(xs, (6, 4))), x.reshape(6, 4))
        close(re.to_plain(re.transpose(xs, (1, 0))), x.T)
        close(re.to_plain(re.sum(xs, axis=-1, keepdims=True)),
              x.sum(-1, keepdims=True))
        close(re.to_plain(re.concat([xs, xs], axis=0)),
              np.concatenate([x, x], 0))
        a, b = re.split(xs, (2, 4), axis=-1)
        close(re.to_plain(a), x[:, :2])
        close(re.to_plain(b), x[:, 2:])
        close(re.to_plain(re.take(xs, np.asarray([2, 0]), axis=0)),
              x[[2, 0]])
        close(re.to_plain(re.scale(xs, 2.0)), x * 2)
        close(re.to_plain(re.scale(xs, 0.3)), x * 0.3)
        close(re.to_plain(re.lincomb_public([(xs, 0.5), (xs, 0.25)])),
              x * 0.75)
        close(re.to_plain(re.mask_public(xs, (x > 0))), x * (x > 0))
        close(re.to_plain(re.mean(xs, -1)), np.asarray(
            pe.to_plain(pe.mean(pe.from_plain(x), -1))), )

    def test_mlp_forward_bit_identical_to_joint_engine(self):
        rng = np.random.RandomState(1)
        net = PML.MLPNet(features=10, layers=(6, 3))
        params_np = PML.mlp_net_init(rng, net)
        X = rng.randn(4, 10)
        te = TridentEngine(make_context(seed=SEED), nonlinear="newton")
        p_joint, _ = PML.mlp_net_fwd(
            te, {k: te.from_plain(v) for k, v in params_np.items()}, net,
            te.from_plain(X))
        re = RuntimeEngine(FourPartyRuntime(RING64, seed=SEED))
        p_rt, _ = PML.mlp_net_fwd(
            re, {k: re.from_plain(v) for k, v in params_np.items()}, net,
            re.from_plain(X))
        assert bool((p_rt.to_joint().data == p_joint.data).all())


# ---------------------------------------------------------------------------
# Tri-world training trajectories (the acceptance criterion).
# ---------------------------------------------------------------------------
class TestTriWorldTrajectories:
    def test_logreg_joint_vs_runtime_bit_identical(self):
        task = SGD.logreg_task(features=6, lr=0.5)
        data = D.RegressionData(features=6, n=256, seed=1, logistic=True)
        pj = task.init_params(seed=0)
        pr = dict(pj)
        for step in range(3):
            batch = data.batch(step, 8)
            pj, lj, aj = SGD.run_step(task, pj, batch, step=step,
                                      base_seed=SEED, world="joint")
            pr, lr_, ar = SGD.run_step(task, pr, batch, step=step,
                                       base_seed=SEED, world="runtime")
            assert lj == lr_ and not (aj or ar)
            for k in pj:
                assert np.array_equal(pj[k], pr[k]), (step, k)

    def test_nn_three_paths_bit_identical_with_zero_offline_bytes(self):
        net = PML.MLPNet(features=12, layers=(8, 4))
        task = SGD.nn_task(net=net, lr=0.5)
        data = D.MNISTLike(n=256, seed=3, features=12, classes=4)
        params = task.init_params(seed=0)
        deal_prog = SGD.deal_step_program(task, params,
                                          data.batch(0, 8)[:2])
        with ContinuousDealer(lambda s: deal_prog, base_seed=SEED,
                              ahead=2, total=3) as dealer:
            sgd = SGD.PrepAheadSGD(task, dealer)
            pj, pr, po = dict(params), dict(params), dict(params)
            for step in range(3):
                b = data.batch(step, 8)[:2]
                pj, lj, _ = SGD.run_step(task, pj, b, step=step,
                                         base_seed=SEED, world="joint")
                pr, lr_, _ = SGD.run_step(task, pr, b, step=step,
                                          base_seed=SEED, world="runtime")
                po, lo, ab = sgd.step_fn(po, step, *b)
                assert lj == lr_ == lo and not ab
                for k in pj:
                    assert np.array_equal(pj[k], pr[k]), (step, k)
                    assert np.array_equal(pj[k], po[k]), (step, k)
                # per-step prep: the online-only run moved ZERO offline
                # bytes (transport-enforced) yet real online traffic
                rep = sgd.reports[-1]
                assert rep.offline_bits == 0
                assert rep.online_bits > 0


# ---------------------------------------------------------------------------
# ContinuousDealer: refill, step-indexed consumption, replay.
# ---------------------------------------------------------------------------
def _tiny_program(rt):
    xs = RT.share(rt, enc(np.ones(3)))
    RT.mult_tr(rt, xs, xs)


class TestContinuousDealer:
    def test_refills_bank_ahead_of_consumer(self):
        with ContinuousDealer(lambda s: _tiny_program, base_seed=0,
                              ahead=2, total=5) as dealer:
            stores = [dealer.next_store() for _ in range(5)]
            assert [s.meta["step"] for s in stores] == list(range(5))
            assert dealer.dealt == 5
            # session k is step k's preprocessing: identical to a direct
            # deal from the step-indexed seed
            ref, _ = deal(_tiny_program, seed=seed_for_step(0, 3))
            assert stores[3].tags() == ref.tags()
            with pytest.raises(PrepError):
                dealer.next_store(timeout=0.5)   # exhausted after total

    def test_store_for_step_seeks_forward_and_replay_raises(self):
        with ContinuousDealer(lambda s: _tiny_program, base_seed=0,
                              ahead=3, total=4) as dealer:
            s2 = dealer.store_for_step(2)        # skips sessions 0, 1
            assert s2.meta["step"] == 2
            with pytest.raises(PrepReplayError) as ei:
                dealer.store_for_step(1)         # backwards = replay
            assert "already consumed" in str(ei.value)
            assert dealer.store_for_step(3).meta["step"] == 3

    def test_dealer_error_surfaces_on_consumer(self):
        def bad_program(rt):
            raise ValueError("boom in the dealer")

        with ContinuousDealer(lambda s: bad_program, base_seed=0,
                              ahead=1, total=2) as dealer:
            with pytest.raises(ValueError, match="boom in the dealer"):
                dealer.next_store(timeout=30.0)


# ---------------------------------------------------------------------------
# Prep errors name tag, kind, and party.
# ---------------------------------------------------------------------------
class TestPrepErrorAttribution:
    def _store(self, party=None):
        store = PrepStore(meta={"step": 4}, party=party)
        store.put("multtr#3", "multtr", [{"lam": np.zeros(2)}] * 4)
        return store

    def test_replay_names_tag_kind_party(self):
        store = self._store(party=2)
        store.pop("multtr#3", "multtr")
        with pytest.raises(PrepReplayError) as ei:
            store.pop("multtr#3", "multtr")
        msg = str(ei.value)
        assert "multtr#3" in msg and "'multtr'" in msg
        assert "party P2" in msg and "step 4" in msg

    def test_missing_names_tag_kind_party(self):
        with pytest.raises(PrepMissingError) as ei:
            self._store(party=1).pop("bext#9.r", "vsh.offline")
        msg = str(ei.value)
        assert "bext#9.r" in msg and "vsh.offline" in msg
        assert "party P1" in msg

    def test_kind_mismatch_names_both_kinds(self):
        with pytest.raises(PrepKindError) as ei:
            self._store().pop("multtr#3", "trunc")
        msg = str(ei.value)
        assert "'multtr'" in msg and "'trunc'" in msg
        assert "all parties" in msg

    def test_for_party_slices_material(self, tmp_path):
        store, _ = deal(_tiny_program, seed=3)
        sliced = store.for_party(2)
        assert sliced.party == 2
        assert sliced.tags() == store.tags()
        assert sliced.nbytes() == store.nbytes(party=2)
        assert sliced.nbytes() < store.nbytes()
        sliced.save(str(tmp_path / "p2"))        # sliced stores serialize
        back = PrepStore.load(str(tmp_path / "p2"))
        assert back.party == 2 and back.tags() == store.tags()


# ---------------------------------------------------------------------------
# Checkpoint/restore x per-step prep: the replayed step consumes the SAME
# tags and reproduces bit-identical params.
# ---------------------------------------------------------------------------
class TestRestoreReplaysPrep:
    def test_restore_replays_step_with_same_tags_bit_identical(
            self, tmp_path):
        task = SGD.logreg_task(features=5, lr=0.5)
        data = D.RegressionData(features=5, n=128, seed=2, logistic=True)
        params0 = task.init_params(seed=0)
        deal_prog = SGD.deal_step_program(task, params0, data.batch(0, 8))
        steps = 5

        def make_trainer(ckpt_dir, dealer, tag_log):
            def step_fn(params, step, *batch):
                store = dealer.store_for_step(step)
                tag_log.append((step, store.tags()))
                program = SGD.step_program(task, params, tuple(batch))
                (new, loss, abort), rep = run_online(program, store)
                assert rep.offline_bits == 0
                return new, loss, abort

            return Trainer(TrainerConfig(steps=steps, ckpt_dir=ckpt_dir,
                                         ckpt_every=2, seed=0),
                           step_fn, dict(params0),
                           lambda s: data.batch(s, 8))

        # uninterrupted reference
        tags_a: list = []
        with ContinuousDealer(lambda s: deal_prog, base_seed=SEED,
                              ahead=2, total=steps) as dealer:
            t1 = make_trainer(str(tmp_path / "a"), dealer, tags_a)
            p_ref = t1.run()

        # crash at step 3, then resume with a FRESH dealer: the resumed
        # step seeks past the spent sessions and replays from the same
        # step-indexed seed
        tags_b: list = []
        with ContinuousDealer(lambda s: deal_prog, base_seed=SEED,
                              ahead=2, total=steps) as dealer:
            t2 = make_trainer(str(tmp_path / "b"), dealer, tags_b)
            with pytest.raises(RuntimeError):
                t2.run(crash_at=3)
        tags_c: list = []
        with ContinuousDealer(lambda s: deal_prog, base_seed=SEED,
                              ahead=2, total=steps) as dealer:
            t3 = make_trainer(str(tmp_path / "b"), dealer, tags_c)
            p_re = t3.run()
        assert any(e.startswith("resumed") for e in t3.events)

        # bit-identical final params, and the replayed steps consumed the
        # SAME prep tag sequences as the uninterrupted run's steps
        for k in p_ref:
            assert np.array_equal(np.asarray(p_ref[k]), np.asarray(p_re[k]))
        ref_tags = dict(tags_a)
        for step, tags in tags_c:
            assert tags == ref_tags[step], step

    def test_retrying_a_consumed_step_raises_replay(self):
        task = SGD.logreg_task(features=4, lr=0.5)
        data = D.RegressionData(features=4, n=64, seed=5, logistic=True)
        params = task.init_params(seed=0)
        deal_prog = SGD.deal_step_program(task, params, data.batch(0, 4))
        with ContinuousDealer(lambda s: deal_prog, base_seed=0, ahead=1,
                              total=2) as dealer:
            sgd = SGD.PrepAheadSGD(task, dealer)
            sgd.step_fn(params, 0, *data.batch(0, 4))
            with pytest.raises(PrepReplayError) as ei:
                sgd.step_fn(params, 0, *data.batch(0, 4))
            assert "already consumed" in str(ei.value)


# ---------------------------------------------------------------------------
# Distributed training on the 4-process socket cluster (slow: spawns).
# ---------------------------------------------------------------------------
class TestClusterSGD:
    def test_logreg_bit_identical_on_cluster_with_prep_ahead(
            self, tmp_path):
        from repro.runtime.net.cluster import PartyCluster

        task = SGD.logreg_task(features=6, lr=0.5)
        data = D.RegressionData(features=6, n=256, seed=1, logistic=True)
        params = task.init_params(seed=0)

        # joint-simulation reference trajectory
        ref, pj = [], dict(params)
        for step in range(3):
            pj, lj, _ = SGD.run_step(task, pj, data.batch(step, 8),
                                     step=step, base_seed=SEED,
                                     world="joint")
            ref.append((dict(pj), lj))

        bank_dir = str(tmp_path / "bank")
        SGD.deal_training_bank(task, params, data.batch(0, 8), 3,
                               base_seed=SEED, path=bank_dir)

        with PartyCluster(prep_path=bank_dir) as cluster:
            # world 3a: interleaved over the socket mesh
            sgd = SGD.ClusterSGD(cluster, task, base_seed=SEED)
            pc = dict(params)
            for step in range(3):
                pc, lc, ab = sgd.step_fn(pc, step, *data.batch(step, 8))
                assert not ab and lc == ref[step][1]
                for k in pc:
                    assert np.array_equal(pc[k], ref[step][0][k])
            assert sgd.offline_bits_on_mesh() > 0    # interleaved: real prep

            # world 3b: prep-ahead -- online-only steps, step-indexed
            # sessions, ZERO offline bytes on the mesh
            sgd2 = SGD.ClusterSGD(cluster, task, base_seed=SEED,
                                  prep="bank")
            pb = dict(params)
            for step in range(3):
                pb, lb, ab = sgd2.step_fn(pb, step, *data.batch(step, 8))
                assert not ab and lb == ref[step][1]
                for k in pb:
                    assert np.array_equal(pb[k], ref[step][0][k])
            assert sgd2.offline_bits_on_mesh() == 0

            # a retried (replayed) step fails loudly, naming the session
            with pytest.raises(RuntimeError, match="already consumed"):
                sgd2.step_fn(pb, 1, *data.batch(1, 8))
