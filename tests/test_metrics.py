"""The live metrics plane: registry mechanics, the exporter HTTP surface,
health probes, the registry-vs-transport consistency contract, the
metrics-enabled socket cluster, and the scripts/ gates.

The central cross-check mirrors test_obs's tracer one: the registry
double-books wire traffic independently of ``MeasuredTransport``, and
``registry.link_bits()`` must equal ``per_link()``'s non-zero cells
EXACTLY -- in process and across the 4-process socket cluster.
"""
import importlib.util
import json
import threading
import time
import urllib.request
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import exporter as obs_exporter
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs.registry import MetricsRegistry
from repro.runtime import FourPartyRuntime
from repro.runtime import protocols as RT

SCRIPTS = Path(__file__).resolve().parent.parent / "scripts"


def _load_script(name):
    spec = importlib.util.spec_from_file_location(name,
                                                  SCRIPTS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture
def registry():
    """Install a fresh labeled registry for the test, restore after."""
    reg = MetricsRegistry("test")
    prev = obs.install_registry(reg)
    try:
        yield reg
    finally:
        obs.install_registry(prev)


def _program(rt):
    x = RT.share(rt, jnp.arange(6, dtype=jnp.int64).reshape(2, 3))
    y = RT.share(rt, jnp.ones((3, 2), dtype=jnp.int64))
    z = RT.matmul(rt, x, y)
    return RT.reconstruct(rt, z)[0]


def _nonzero_links(per_link):
    out = {}
    for link, per in per_link.items():
        cell = {p: b for p, b in per.items() if b}
        if cell:
            out[link] = cell
    return out


# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics(registry):
    c = registry.counter("c_total", "a counter", kind="x")
    c.inc()
    c.inc(4)
    assert c.value == 5 and c.updated > 0
    # same (name, labels) -> same object; new labels -> new sample
    assert registry.counter("c_total", kind="x") is c
    registry.counter("c_total", kind="y").inc(2)
    assert registry.total("c_total") == 7

    g = registry.gauge("g", "a gauge")
    g.set(3)
    v, ts = g.read()
    assert (v, ts > 0) == (3, True)

    h = registry.histogram("h_us", "a histogram")
    h.observe(50.0)
    assert h.count == 1 and h.sum == 50.0
    assert registry.total("h_us") == 1   # histograms total their counts


def test_type_conflict_raises(registry):
    registry.counter("m")
    with pytest.raises(ValueError, match="already registered"):
        registry.gauge("m")


def test_histogram_edges_match_trace_histogram(registry):
    """Boundary parity with metrics._histogram: a value landing exactly
    on an edge goes to the NEXT bucket in both implementations."""
    values = [0.0, 9.9, 10.0, 99.0, 100.0, 1_000.0, 99_999.0,
              100_000.0, 5e6]
    h = registry.histogram("h_us")
    for v in values:
        h.observe(v)
    assert h.buckets == obs_metrics._histogram(values)["counts"]
    assert list(h.edges) == list(obs_metrics._HIST_EDGES_US)


def test_snapshot_is_json_clean_and_readable(registry):
    registry.counter("trident_wire_bits_total", src=0, dst=1,
                     phase="online").inc(128)
    registry.gauge("depth").set(7)
    registry.histogram("lat_us").observe(42.0)
    snap = registry.snapshot()
    json.dumps(snap)                     # plain data end to end
    assert snap["label"] == "test"
    assert obs.snapshot_total(snap, "trident_wire_bits_total") == 128
    assert obs.snapshot_value(snap, "trident_wire_bits_total",
                              src=0, dst=1, phase="online") == 128
    assert obs.snapshot_value(snap, "depth") == 7
    assert obs.snapshot_value(snap, "absent", default=None) is None
    assert obs.snapshot_updated(snap, "depth") > 0
    assert obs.snapshot_updated(snap, "absent") == 0.0
    assert obs.snapshot_link_bits(snap) == {(0, 1): {"online": 128}}


def test_render_prometheus_exposition(registry):
    registry.counter("c_total", "help text", kind="x").inc(3)
    registry.histogram("h_us").observe(5.0)
    text = registry.render_prometheus()
    assert "# HELP c_total help text" in text
    assert "# TYPE c_total counter" in text
    assert 'c_total{kind="x"} 3' in text
    assert 'h_us_bucket{le="10.0"} 1' in text
    assert 'h_us_bucket{le="+Inf"} 1' in text
    assert "h_us_count 1" in text


def test_concurrent_updates_never_lose_increments(registry):
    c = registry.counter("c_total")
    g = registry.gauge("g")
    h = registry.histogram("h_us")
    N, THREADS = 10_000, 8

    def work(tid):
        for i in range(N):
            c.inc()
            g.set(tid)
            h.observe(float(i % 200))

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == N * THREADS
    assert h.count == N * THREADS
    assert sum(h.buckets) == h.count
    assert g.read()[0] in range(THREADS)


def test_metrics_env_gates_exporters_not_registry(monkeypatch):
    monkeypatch.delenv(obs.METRICS_ENV, raising=False)
    assert not obs.metrics_enabled()
    monkeypatch.setenv(obs.METRICS_ENV, "1")
    assert obs.metrics_enabled()
    # the registry itself is always on regardless
    monkeypatch.delenv(obs.METRICS_ENV, raising=False)
    assert isinstance(obs.get_registry(), MetricsRegistry)


# ---------------------------------------------------------------------------
# The consistency contract, in process.
# ---------------------------------------------------------------------------
def test_registry_link_bits_equal_per_link(registry):
    # the transport captures the registry at construction: install first
    rt = FourPartyRuntime(seed=7)
    _program(rt)
    assert registry.link_bits() == _nonzero_links(rt.transport.per_link())
    assert registry.total("trident_wire_msgs_total") > 0
    assert registry.total("trident_wire_round_scopes_total") > 0


def test_protocol_and_kernel_counters(registry):
    rt = FourPartyRuntime(seed=8)
    _program(rt)
    snap = registry.snapshot()
    for proto in ("share", "matmul", "reconstruct"):
        assert obs.snapshot_value(snap, "trident_protocol_calls_total",
                                  protocol=proto) > 0, proto
    assert obs.snapshot_total(snap, "trident_protocol_checks_total") > 0
    assert obs.snapshot_total(snap, "trident_kernel_launches_total") > 0


# ---------------------------------------------------------------------------
# The exporter HTTP surface.
# ---------------------------------------------------------------------------
def test_exporter_serves_registry_over_http():
    reg = MetricsRegistry("exported")
    reg.counter("c_total", "c").inc(11)
    with obs_exporter.MetricsExporter(reg) as exp:
        snap = obs_health.scrape(exp.port)
        assert snap["label"] == "exported"
        assert obs.snapshot_total(snap, "c_total") == 11
        base = f"http://127.0.0.1:{exp.port}"
        with urllib.request.urlopen(f"{base}/metrics", timeout=5) as r:
            assert b"c_total 11" in r.read()
        with urllib.request.urlopen(f"{base}/healthz", timeout=5) as r:
            assert json.loads(r.read())["label"] == "exported"
    # closed: scrapes now fail cleanly
    assert obs_health._try_scrape(exp.port, timeout=0.5) is None
    assert obs_health._try_scrape(None, timeout=0.5) is None


# ---------------------------------------------------------------------------
# Trace-side metrics helpers (satellite: round_wall_ms + edge cases).
# ---------------------------------------------------------------------------
def test_round_wall_ms_pid_returns_flat_phases():
    doc = {"traceEvents": [
        {"ph": "X", "cat": "wire.round", "pid": 2, "ts": 0.0,
         "dur": 3000.0, "args": {"phase": "online"}},
        {"ph": "X", "cat": "wire.round", "pid": 2, "ts": 0.0,
         "dur": 1000.0, "args": {"phase": "offline"}},
        {"ph": "X", "cat": "wire.round", "pid": 3, "ts": 0.0,
         "dur": 500.0, "args": {"phase": "online"}},
    ]}
    assert obs.round_wall_ms(doc, pid=2) == {"online": 3.0, "offline": 1.0}
    assert obs.round_wall_ms(doc, pid=99) == {}
    nested = obs.round_wall_ms(doc)
    assert nested[2]["online"] == 3.0 and nested[3]["online"] == 0.5


def test_metrics_snapshot_empty_doc():
    snap = obs.metrics_snapshot({"traceEvents": []})
    assert snap == {"spans": {}, "rounds": {}, "sends": {},
                    "counters": {}}


def test_metrics_snapshot_counter_only_doc():
    doc = {"traceEvents": [
        {"ph": "C", "name": "depth", "pid": 0, "ts": 0.0,
         "args": {"value": 5}},
        {"ph": "C", "name": "depth", "pid": 0, "ts": 1.0,
         "args": {"value": 2}},
    ]}
    snap = obs.metrics_snapshot(doc)
    assert snap["counters"]["depth"] == {"last": 2, "max": 5}
    assert snap["spans"] == {} and snap["rounds"] == {}


# ---------------------------------------------------------------------------
# Health probes over synthetic snapshots (pure, offline).
# ---------------------------------------------------------------------------
def _rank_snap(*, inflight=0, depth=None, next_session=0):
    reg = MetricsRegistry("synthetic")
    reg.gauge("trident_cluster_tasks_inflight").set(inflight)
    if inflight:
        reg.counter("trident_wire_round_scopes_total", phase="online").inc()
    if depth is not None:
        reg.gauge("trident_live_bank_depth").set(depth)
    reg.gauge("trident_prep_next_session").set(next_session)
    return reg.snapshot()


def _dealer_snap(*, watermark=0, done=0):
    reg = MetricsRegistry("synthetic-dealer")
    reg.gauge("trident_dealer_watermark").set(watermark)
    reg.gauge("trident_dealer_done").set(done)
    return reg.snapshot()


def test_probe_round_stall_is_age_gated():
    snap = _rank_snap(inflight=1)
    t0 = time.time()
    assert obs_health.evaluate_probes({0: snap}, now=t0 + 1,
                                      stall_s=5.0) == []
    probes = obs_health.evaluate_probes({0: snap}, now=t0 + 10,
                                        stall_s=5.0)
    assert [p["probe"] for p in probes] == ["round_stall"]
    assert probes[0]["rank"] == 0 and probes[0]["stalled_s"] > 5.0
    # idle ranks never stall, however old the snapshot
    idle = _rank_snap(inflight=0)
    assert obs_health.evaluate_probes({0: idle}, now=t0 + 100,
                                      stall_s=5.0) == []


def test_probe_bank_low_requires_attached_undone_dealer():
    snap = _rank_snap(inflight=1, depth=0)
    dealer = _dealer_snap(watermark=5)
    t0 = time.time()
    probes = obs_health.evaluate_probes({0: snap}, dealer, now=t0 + 10,
                                        stall_s=5.0, dealer_attached=True)
    assert "bank_low" in {p["probe"] for p in probes}
    # a finished dealer makes an empty bank normal
    done = _dealer_snap(watermark=5, done=1)
    probes = obs_health.evaluate_probes({0: snap}, done, now=t0 + 10,
                                        stall_s=5.0, dealer_attached=True)
    assert "bank_low" not in {p["probe"] for p in probes}
    # no dealer attached: local banks drain by design
    probes = obs_health.evaluate_probes({0: snap}, None, now=t0 + 10,
                                        stall_s=5.0, dealer_attached=False)
    assert "bank_low" not in {p["probe"] for p in probes}


def test_probe_dealer_lag():
    snap = _rank_snap(next_session=7)
    t0 = time.time()
    lagging = _dealer_snap(watermark=2)
    probes = obs_health.evaluate_probes({0: snap}, lagging, now=t0 + 10,
                                        stall_s=5.0, dealer_attached=True)
    assert [p["probe"] for p in probes] == ["dealer_lag"]
    assert (probes[0]["wanted"], probes[0]["watermark"]) == (7, 2)
    # caught-up watermark, or a freshly-moved one, is fine
    ahead = _dealer_snap(watermark=9)
    assert obs_health.evaluate_probes({0: snap}, ahead, now=t0 + 10,
                                      stall_s=5.0,
                                      dealer_attached=True) == []
    assert obs_health.evaluate_probes({0: snap}, lagging, now=t0 + 1,
                                      stall_s=5.0,
                                      dealer_attached=True) == []


# ---------------------------------------------------------------------------
# The metrics-enabled 4-process cluster.
# ---------------------------------------------------------------------------
def _cluster_program(rt, rank):
    return np.asarray(_program(rt))


def test_cluster_metrics_ports_scrape_and_health():
    from repro.runtime.net.cluster import PartyCluster

    with PartyCluster(timeout=90.0, metrics=True) as cluster:
        assert sorted(cluster.metrics_ports) == [0, 1, 2, 3]
        assert all(p for p in cluster.metrics_ports.values())
        results = cluster.submit(_cluster_program, seed=11)

        # the consistency contract over the real wire: each daemon's
        # registry equals the task's full per-link accounting
        for r in results:
            assert r.metrics is not None
            assert obs.snapshot_link_bits(r.metrics) == \
                _nonzero_links(r.per_link), f"P{r.rank}"
            assert obs.snapshot_value(
                r.metrics, "trident_cluster_tasks_total") == 1
            assert obs.snapshot_value(
                r.metrics, "trident_cluster_tasks_inflight") == 0

        # live scrape of the daemons' exporters between tasks
        snaps = cluster.scrape()
        assert sorted(snaps) == [0, 1, 2, 3]
        for rank, snap in snaps.items():
            assert snap is not None and snap["rank"] == rank
            assert obs.snapshot_total(snap, "trident_wire_bits_total") > 0

        doc = cluster.health()
        assert doc["healthy"], doc
        assert sorted(doc["ranks"]) == [0, 1, 2, 3]
        for entry in doc["ranks"].values():
            assert entry["alive"] and entry["scrape_ok"]
            assert entry["tasks"] == 1
        json.dumps(doc)                  # ships to CI as JSON


def test_cluster_without_metrics_has_no_ports():
    from repro.runtime.net.cluster import PartyCluster

    with PartyCluster(timeout=90.0) as cluster:
        results = cluster.submit(_cluster_program, seed=11)
        assert all(p is None for p in cluster.metrics_ports.values())
        assert all(r.metrics is None for r in results)


# ---------------------------------------------------------------------------
# The scripts/ gates (importable, tested offline).
# ---------------------------------------------------------------------------
def _bench_doc(**overrides):
    rec = {"bench": "netbench", "block": "b", "kernel_backend": "jnp",
           "online_bits": 1024, "online_rounds": 7, "bit_identical": True,
           "wan_online_s": 0.125, "wall_ms": 40.0, "launch_wall_s": 2.0}
    rec.update(overrides)
    return {"bench": "netbench", "records": [rec]}


def test_bench_compare_classification():
    bc = _load_script("bench_compare")
    base = _bench_doc()
    # identical -> clean
    assert bc.compare(base, _bench_doc())["regressions"] == []
    # measured noise below tol*floor -> clean; past both -> regression
    assert bc.compare(base, _bench_doc(wall_ms=150.0),
                      tol=5.0)["regressions"] == []
    slow = bc.compare(base, _bench_doc(wall_ms=450.0), tol=5.0)
    assert [r["kind"] for r in slow["regressions"]] == ["measured"]
    # the floor keeps small absolute jitter from tripping the multiplier
    tiny = _bench_doc(wall_ms=0.001)
    assert bc.compare(tiny, _bench_doc(wall_ms=0.1))["regressions"] == []
    # modeled drift and exact-int drift always fail
    drift = bc.compare(base, _bench_doc(wan_online_s=0.126))
    assert [r["kind"] for r in drift["regressions"]] == ["modeled"]
    bits = bc.compare(base, _bench_doc(online_bits=1025))
    assert [r["kind"] for r in bits["regressions"]] == ["exact"]
    flipped = bc.compare(base, _bench_doc(bit_identical=False))
    assert [r["kind"] for r in flipped["regressions"]] == ["exact"]
    # missing block / key regress; extra keys are notes
    gone = bc.compare(base, {"bench": "netbench", "records": []})
    assert [r["kind"] for r in gone["regressions"]] == ["missing_block"]
    fresh = _bench_doc(extra_key=1.0)
    del fresh["records"][0]["wall_ms"]
    diff = bc.compare(base, fresh)
    assert [r["kind"] for r in diff["regressions"]] == ["missing_key"]
    assert diff["notes"][0]["extra_keys"] == ["extra_key"]


def _health_doc(**overrides):
    doc = {"healthy": True, "scrapes": 5, "probes": [],
           "probes_fired_ever": [],
           "ranks": {str(r): {"alive": True, "scrape_ok": True,
                              "port": 4000 + r} for r in range(4)},
           "dealer": {"alive": True, "port": 5000, "scrape_ok": True,
                      "dealt": 3, "done": True}}
    doc.update(overrides)
    return doc


def test_check_health_gate(tmp_path):
    ch = _load_script("check_health")
    path = tmp_path / "health.json"
    path.write_text(json.dumps(_health_doc()))
    info = ch.check(str(path), expect_dealer=True)
    assert info["ranks"] == 4 and info["scrapes"] == 5

    path.write_text(json.dumps(_health_doc(
        probes_fired_ever=[{"probe": "round_stall", "rank": 1}],
        healthy=False)))
    with pytest.raises(AssertionError, match="unhealthy"):
        ch.check(str(path))

    undone = _health_doc()
    undone["dealer"]["done"] = False
    path.write_text(json.dumps(undone))
    ch.check(str(path))                  # fine without --expect-dealer
    with pytest.raises(AssertionError, match="quota"):
        ch.check(str(path), expect_dealer=True)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-x", "-q"]))
