"""The observability plane: tracer mechanics, trace-consistency against
the transport's own accounting, merging, metrics, and the traced
4-process cluster path.

The central cross-check: the tracer double-books wire traffic
independently of ``MeasuredTransport``, and the two must agree EXACTLY
(per link, per phase) -- any drift means an instrumented seam missed or
double-counted a send.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.runtime import FourPartyRuntime, LocalTransport
from repro.runtime import activations as ACT
from repro.runtime import protocols as RT


@pytest.fixture
def tracer():
    """Install a fresh enabled Tracer for the test, restore after."""
    tr = obs.Tracer("test")
    prev = obs.install_tracer(tr)
    try:
        yield tr
    finally:
        obs.install_tracer(prev)


def _program(rt):
    x = RT.share(rt, jnp.arange(6, dtype=jnp.int64).reshape(2, 3))
    y = RT.share(rt, jnp.ones((3, 2), dtype=jnp.int64))
    z = RT.matmul(rt, x, y)
    r = ACT.relu(rt, z)
    return RT.reconstruct(rt, r)[0]


# ---------------------------------------------------------------------------
# Off-by-default.
# ---------------------------------------------------------------------------
def test_tracing_off_by_default(monkeypatch):
    monkeypatch.delenv(obs.TRACE_ENV, raising=False)
    prev = obs.install_tracer(None)     # reset the lazy singleton
    try:
        assert obs.get_tracer() is obs.NULL_TRACER
        rt = FourPartyRuntime()
        assert not rt.tracer.enabled
        assert rt.transport.tracer is obs.NULL_TRACER
        _program(rt)                    # protocols run untraced
        assert obs.NULL_TRACER.drain() is None
    finally:
        obs.install_tracer(prev)


def test_env_var_enables(monkeypatch):
    monkeypatch.setenv(obs.TRACE_ENV, "1")
    prev = obs.install_tracer(None)
    try:
        assert obs.get_tracer().enabled
    finally:
        obs.install_tracer(prev)


# ---------------------------------------------------------------------------
# Trace consistency: traced bytes == transport accounting, exactly.
# ---------------------------------------------------------------------------
def test_traced_link_bits_equal_per_link(tracer):
    rt = FourPartyRuntime(seed=7)
    _program(rt)
    traced = tracer.link_bits()
    measured = rt.transport.per_link()
    # every measured non-zero cell is traced with the same value...
    for link, per in measured.items():
        for phase, bits in per.items():
            if bits:
                assert traced[link][phase] == bits, (link, phase)
    # ...and the trace saw nothing the transport didn't measure
    for link, per in traced.items():
        for phase, bits in per.items():
            assert measured[link][phase] == bits, (link, phase)


def test_drain_resets_and_is_json_clean(tracer):
    rt = FourPartyRuntime(seed=1)
    _program(rt)
    chunk = tracer.drain()
    assert chunk["label"] == "test"
    assert chunk["events"], "no events traced"
    import json
    json.dumps(chunk)                    # plain data end to end
    # drained: the next chunk starts empty
    again = tracer.drain()
    assert again["events"] == [] and again["link_bits"] == {}


def test_span_taxonomy_covers_all_layers(tracer):
    rt = FourPartyRuntime(seed=2)
    _program(rt)
    cats = {e["cat"] for e in tracer.drain()["events"]}
    for expected in ("protocol", "wire.round", "wire.send", "kernel"):
        assert expected in cats, (expected, cats)


def test_protocol_spans_carry_prep_and_check_attribution(tracer):
    rt = FourPartyRuntime(seed=3)
    _program(rt)
    spans = [e for e in tracer.drain()["events"]
             if e["cat"] == "protocol"]
    names = {e["name"] for e in spans}
    assert {"share", "matmul", "relu", "reconstruct"} <= names
    mm = next(e for e in spans if e["name"] == "matmul")
    assert mm["args"]["prep"] == "inline"
    assert mm["args"]["checks"] > 0      # malicious checks recorded


def test_round_spans_carry_phase_index_bits(tracer):
    rt = FourPartyRuntime(seed=4)
    _program(rt)
    rounds = [e for e in tracer.drain()["events"]
              if e["cat"] == "wire.round"]
    assert rounds
    online = [e for e in rounds if e["args"]["phase"] == "online"]
    assert [e["args"]["index"] for e in online] == list(range(len(online)))
    assert all(e["args"]["bits"] > 0 for e in rounds)
    # every analytic round has at least one traced scope; spans can
    # exceed the analytic count because parallel-overlapped scopes
    # max-merge in the tally but each emits its own span
    per_phase = {p: sum(1 for e in rounds if e["args"]["phase"] == p)
                 for p in ("offline", "online")}
    for p in ("offline", "online"):
        assert per_phase[p] >= rt.transport.rounds[p], (p, per_phase)


# ---------------------------------------------------------------------------
# Merging + metrics.
# ---------------------------------------------------------------------------
def _chunk(label, rank, epoch, events):
    return {"label": label, "rank": rank, "epoch": epoch,
            "events": events, "link_bits": {}}


def test_merge_aligns_clocks_across_processes():
    # same absolute instant, different perf_counter origins
    a = _chunk("A", 0, 100.0, [{"ph": "i", "name": "x", "cat": "c",
                                "ts": 5.0}])
    b = _chunk("B", 1, 90.0, [{"ph": "i", "name": "y", "cat": "c",
                               "ts": 15.0}])
    doc = obs.merge_chunks([a, b, None])
    evs = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert {e["ts"] for e in evs} == {0.0}      # both at t=0, aligned
    assert doc["metadata"]["ranks"] == [0, 1]
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert names == {"A", "B"}


def test_merge_spans_use_microseconds():
    a = _chunk("A", 0, 0.0, [{"ph": "X", "name": "s", "cat": "c",
                              "ts": 1.0, "dur": 0.002}])
    doc = obs.merge_chunks([a])
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert span["dur"] == pytest.approx(2000.0)


def test_merged_link_bits_takes_max_not_sum():
    # replicated-program model: every rank carries the FULL per-link
    # picture, so merging must not multiply it by four
    a = _chunk("A", 0, 0.0, [])
    b = _chunk("B", 1, 0.0, [])
    a["link_bits"] = {"0->1": {"online": 128}}
    b["link_bits"] = {"0->1": {"online": 128}, "2->3": {"offline": 64}}
    merged = obs.merged_link_bits([a, b])
    assert merged == {"0->1": {"online": 128}, "2->3": {"offline": 64}}


def test_metrics_snapshot(tracer):
    rt = FourPartyRuntime(seed=5)
    _program(rt)
    tracer.counter("depth", 3)
    tracer.counter("depth", 1)
    doc = obs.merge_chunks([tracer.drain()])
    snap = obs.metrics_snapshot(doc)
    assert snap["rounds"]["online"]["count"] == rt.transport.rounds["online"]
    assert snap["rounds"]["online"]["wall_ms"] > 0
    assert snap["sends"]["online"]["bits"] == \
        rt.transport.phase_bits["online"]
    assert snap["spans"]["protocol"]["count"] >= 4
    hist = snap["spans"]["protocol"]["hist"]
    assert sum(hist["counts"]) == snap["spans"]["protocol"]["count"]
    assert snap["counters"]["depth"] == {"last": 1, "max": 3}


def test_round_wall_ms(tracer):
    rt = FourPartyRuntime(seed=6)
    _program(rt)
    doc = obs.merge_chunks([tracer.drain()])
    walls = obs.round_wall_ms(doc)
    (pid,) = walls.keys()
    assert walls[pid]["online"] > 0


# ---------------------------------------------------------------------------
# The timed/stopwatch consolidation helpers (serve-layer bookkeeping).
# ---------------------------------------------------------------------------
class _Stats:
    compute_s = 0.0
    online_compute_s = 0.0


def test_timed_accumulates_multiple_attrs(tracer):
    st = _Stats()
    with obs.timed(st, "compute_s", "online_compute_s", span="work"):
        pass
    assert st.compute_s > 0
    assert st.compute_s == st.online_compute_s
    before = st.compute_s
    with obs.timed(st, "compute_s"):
        pass
    assert st.compute_s > before         # accumulates, not overwrites
    names = [e["name"] for e in tracer.drain()["events"]]
    assert names == ["work"]             # span=None records nothing


def test_timed_without_tracer_still_accumulates():
    prev = obs.install_tracer(obs.NULL_TRACER)
    try:
        st = _Stats()
        with obs.timed(st, "compute_s", span="work"):
            pass
        assert st.compute_s > 0
    finally:
        obs.install_tracer(prev)


def test_stopwatch():
    with obs.stopwatch() as sw:
        pass
    assert sw.s >= 0.0


# ---------------------------------------------------------------------------
# The traced 4-process cluster (acceptance path, minus the dealer).
# ---------------------------------------------------------------------------
def _cluster_program(rt, rank):
    return np.asarray(_program(rt))


def test_cluster_trace_covers_all_ranks_and_matches_per_link():
    from repro.runtime.net.cluster import PartyCluster

    with PartyCluster(timeout=90.0, trace=True) as cluster:
        results = cluster.submit(_cluster_program, seed=11)
        assert cluster.trace
        chunks = cluster.trace_chunks
        assert len(chunks) == 4
        assert sorted(c["rank"] for c in chunks) == [0, 1, 2, 3]
        # trace consistency on the real wire: every rank's traced bytes
        # equal the full per-link accounting (replicated-program model)
        for r in results:
            chunk = r.trace
            assert chunk is not None and chunk["rank"] == r.rank
            traced = chunk["link_bits"]
            for (s, d), per in r.per_link.items():
                for phase, bits in per.items():
                    if bits:
                        assert traced[f"{s}->{d}"][phase] == bits
            assert r.prep_wait_s == 0.0  # no prep on this path
        doc = cluster.merged_trace()
        pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
        assert len(pids) == 4
        snap = obs.metrics_snapshot(doc)
        assert snap["rounds"]["online"]["count"] > 0
        assert len(cluster.task_walls) == 1 and cluster.task_walls[0] > 0


def test_cluster_untraced_ships_no_chunks():
    from repro.runtime.net.cluster import PartyCluster

    with PartyCluster(timeout=90.0) as cluster:
        results = cluster.submit(_cluster_program, seed=11)
        assert cluster.trace_chunks == []
        assert all(r.trace is None for r in results)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-x", "-q"]))
