"""Secure SGD training across four OS processes, with per-step prep.

The acceptance demo of the RuntimeEngine refactor: the SAME engine-generic
training step (``paper_ml.logreg_step`` driven by
``train.secure_sgd.SGDTask``) runs three ways --

  1. TridentEngine: the joint simulation (newton nonlinearities),
  2. RuntimeEngine over the in-memory LocalTransport,
  3. RuntimeEngine inside four OS processes over the TCP mesh
     (``PartyCluster``), first interleaved, then PREP-AHEAD: a PrepBank
     with one session per training step is dealt up front and the daemons
     load it at startup, so every step executes online-only -- the mesh
     carries ZERO offline bytes, transport-enforced --

and the script checks the (params, loss) trajectories are *bit-identical*
across all paths, step by step, from the same step-indexed seeds.

    PYTHONPATH=src python examples/secure_training_parties.py
"""
import tempfile
import time

import numpy as np

from repro.train import data as D
from repro.train import secure_sgd as SGD
from repro.runtime.net.cluster import PartyCluster

SEED = 17
STEPS = 3
BATCH = 8

task = SGD.logreg_task(features=6, lr=0.5)
data = D.RegressionData(features=6, n=256, seed=1, logistic=True)
params0 = task.init_params(seed=0)


def trajectory(step_fn):
    params, losses = dict(params0), []
    for step in range(STEPS):
        params, loss, abort = step_fn(params, step, *data.batch(step, BATCH))
        assert not abort
        losses.append(loss)
    return params, losses


def world_step(world):
    def step_fn(params, step, *batch):
        return SGD.run_step(task, params, batch, step=step,
                            base_seed=SEED, world=world)
    return step_fn


def main():
    print(f"secure logreg SGD, {STEPS} steps, batch {BATCH} "
          f"(step seeds {SEED}+t)\n")
    p_joint, l_joint = trajectory(world_step("joint"))
    print(f"[joint sim]        losses {['%.6f' % l for l in l_joint]}")
    p_local, l_local = trajectory(world_step("runtime"))
    print(f"[runtime local]    losses {['%.6f' % l for l in l_local]}")

    # per-step prep: session t of the bank IS step t's offline material
    bank_dir = tempfile.mkdtemp(prefix="trident_train_bank_")
    _, reports = SGD.deal_training_bank(task, params0, data.batch(0, BATCH),
                                        STEPS, base_seed=SEED,
                                        path=bank_dir)
    print(f"[dealer]           {STEPS} sessions, "
          f"{reports[0].entries} entries/step -> {bank_dir}")

    t0 = time.time()
    with PartyCluster(prep_path=bank_dir) as cluster:
        p_sock, l_sock = trajectory(
            SGD.ClusterSGD(cluster, task, base_seed=SEED))
        print(f"[4-proc sockets]   losses {['%.6f' % l for l in l_sock]}")
        prep_sgd = SGD.ClusterSGD(cluster, task, base_seed=SEED,
                                  prep="bank")
        p_prep, l_prep = trajectory(prep_sgd)
        print(f"[4-proc prep-ahead] losses {['%.6f' % l for l in l_prep]} "
              f"(offline bits on mesh: {prep_sgd.offline_bits_on_mesh()})")
        assert prep_sgd.offline_bits_on_mesh() == 0
    wall = time.time() - t0

    for other in (p_local, p_sock, p_prep):
        for k in p_joint:
            assert np.array_equal(np.asarray(p_joint[k]),
                                  np.asarray(other[k]))
    assert l_joint == l_local == l_sock == l_prep
    print(f"\nall four trajectories BIT-IDENTICAL "
          f"(cluster wall {wall:.1f}s)")


if __name__ == "__main__":
    main()
