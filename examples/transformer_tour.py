"""Tour: train + decode a (reduced) assigned architecture under 4PC.

    PYTHONPATH=src python examples/transformer_tour.py --arch qwen3-1.7b
"""
import argparse

import numpy as np

from repro import configs as CFGS
from repro.core.context import make_context
from repro.core.costs import LAN, WAN
from repro.nn.engine import TridentEngine
from repro.nn import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    help=f"one of {sorted(CFGS.ALIASES)}")
    ap.add_argument("--steps", type=int, default=2)
    args = ap.parse_args()

    cfg = CFGS.get(args.arch).SMOKE
    print(f"arch {args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model} {cfg.family})")
    rng = np.random.RandomState(0)
    ctx = make_context(seed=0, collapse=True)
    eng = TridentEngine(ctx)
    params = M.params_to_engine(eng, M.init_params(cfg, seed=0))

    B, S = 2, 8
    kw = {}
    if cfg.family == "vlm":
        kw["frontend_embs"] = eng.from_plain(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model) * 0.1)
    if cfg.family == "encdec":
        kw["enc_inputs"] = eng.from_plain(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model) * 0.1)
    for step in range(args.steps):
        ids = rng.randint(0, cfg.vocab, (B, S))
        labels = rng.randint(0, cfg.vocab, (B, S))
        params, loss, _ = M.train_step(eng, cfg, params, ids, labels,
                                       lr=2.0 ** -6, **kw)
        print(f"  step {step}: loss {float(loss):.4f}  "
              f"abort={bool(ctx.abort_flag())}")

    if cfg.family not in ("encdec", "vlm"):
        ids = rng.randint(0, cfg.vocab, (B, S + 1))
        _, caches = M.serve_prefill(eng, cfg, params, ids[:, :S])
        logits, _ = M.serve_decode(eng, cfg, params, ids[:, S:], caches,
                                   pos=S)
        tok = np.argmax(np.asarray(eng.to_plain(logits))[:, 0], -1)
        print(f"  decoded next tokens: {tok}")

    r, b = ctx.tally.online.rounds, ctx.tally.online.bits
    print(f"total online: {r} rounds, {b/8e6:.2f} MB "
          f"(LAN {LAN.seconds(r, b)*1e3:.0f} ms / WAN {WAN.seconds(r, b):.1f} s)")


if __name__ == "__main__":
    main()
