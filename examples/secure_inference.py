"""Batched secure prediction serving (paper Section VI-B).

    PYTHONPATH=src python examples/secure_inference.py
"""
import numpy as np

from repro.core.context import make_context
from repro.nn.engine import TridentEngine
from repro.serve.engine import PredictionServer
from repro.train import data as D, paper_ml as PML

rng = np.random.RandomState(0)
net = PML.MLPNet(features=64, layers=(32, 10))
params_np = PML.mlp_net_init(rng, net)
data = D.MNISTLike(n=512, seed=1, features=64)


def predict(ctx, X):
    eng = TridentEngine(ctx)
    params = {k: eng.from_plain(v) for k, v in params_np.items()}
    p, _ = PML.mlp_net_fwd(eng, params, net, eng.from_plain(X))
    return eng.to_plain(p)


srv = PredictionServer(predict, batch_size=32)
X, _, labels = data.batch(0, 96)
for x in X:
    srv.submit(x)
preds = srv.flush()
print(f"served {len(preds)} queries in {srv.stats.batches} secure batches")
for k, v in srv.report().items():
    print(f"  {k:22s} {v:.4g}")
