"""Secure prediction end-to-end across four Party instances.

A small square-activation MLP (CryptoNets-style: matmul_tr -> square via
mult_tr -> matmul_tr) runs twice -- once on the joint simulation, once on
the party-sliced runtime -- and the script checks that

  * the reconstructed predictions are bit-identical between the backends,
  * the bytes/rounds measured on the LocalTransport equal the joint
    trace's analytic CostTally,

then serves a batch stream through PartyPredictionServer and prints the
measured per-link online traffic.

    PYTHONPATH=src python examples/secure_inference_parties.py
"""
import numpy as np

from repro.core import protocols as PR
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.runtime import FourPartyRuntime, protocols as RT
from repro.serve.party_server import PartyPredictionServer

rng = np.random.RandomState(0)
D, H, O, BATCH = 16, 8, 3, 8
W1 = rng.randn(D, H) * 0.3
W2 = rng.randn(H, O) * 0.3
X = rng.randn(BATCH, D)


def predict_joint(ctx, Xb):
    ring = ctx.ring
    xs = PR.share(ctx, ring.encode(Xb))
    w1 = PR.share(ctx, ring.encode(W1))
    w2 = PR.share(ctx, ring.encode(W2))
    h = PR.matmul_tr(ctx, xs, w1)
    a = PR.mult_tr(ctx, h, h)                      # square activation
    out = PR.matmul_tr(ctx, a, w2)
    return PR.reconstruct(ctx, out)


def predict_parties(rt, Xb):
    ring = rt.ring
    xs = RT.share(rt, ring.encode(Xb))
    w1 = RT.share(rt, ring.encode(W1))
    w2 = RT.share(rt, ring.encode(W2))
    h = RT.matmul_tr(rt, xs, w1)
    a = RT.mult_tr(rt, h, h)
    out = RT.matmul_tr(rt, a, w2)
    opened = RT.reconstruct(rt, out)
    # every receiver opened the same value; serve P1's copy
    return np.asarray(opened[1])


# --- cross-check: joint simulation vs four parties on the wire -------------
ctx = make_context(RING64, seed=11)
ref = np.asarray(predict_joint(ctx, X))

rt = FourPartyRuntime(RING64, seed=11)
got = predict_parties(rt, X)

assert np.array_equal(ref, got), "party-sliced != joint simulation"
assert rt.transport.totals() == ctx.tally.totals(), \
    f"measured {rt.transport.totals()} != tally {ctx.tally.totals()}"
assert not bool(rt.abort_flag())
print("bit-identical predictions across backends ✓")
print(f"measured == analytic tally ✓  {rt.transport.totals()}")
print("plaintext check:",
      np.allclose(RING64.decode(got), (X @ W1) ** 2 @ W2, atol=0.05))

# --- serve a query stream through the party runtime ------------------------
srv = PartyPredictionServer(
    lambda r, Xb: RING64.decode(predict_parties(r, Xb)), batch_size=BATCH,
    seed=11)
for x in rng.randn(3 * BATCH, D):
    srv.submit(x)
preds = srv.flush()
print(f"\nserved {len(preds)} queries in {srv.stats.batches} secure batches")
for k, v in srv.report().items():
    if k == "link_online_bits":
        print("  measured online bits per directed link:")
        for link, bits in v.items():
            print(f"    {link}: {bits}")
    else:
        print(f"  {k:24s} {v}")
