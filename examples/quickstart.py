"""Quickstart: the Trident 4PC protocol suite in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.context import make_context
from repro.core import protocols as PR, conversions as CV, activations as ACT

ctx = make_context(seed=0)           # F_setup keys + cost tally
ring = ctx.ring                      # Z_2^64, 13 fractional bits

# --- secret-share two private matrices (Pi_Sh) --------------------------
A = np.random.RandomState(0).randn(4, 6)
B = np.random.RandomState(1).randn(6, 3)
a, b = PR.share(ctx, ring.encode(A)), PR.share(ctx, ring.encode(B))

# --- secure matmul with free truncation (Pi_MatMulTr, Fig. 18) ----------
c = PR.matmul_tr(ctx, a, b)

# --- secure comparison + ReLU (Fig. 19 + BitInj) ------------------------
r = ACT.relu(ctx, c)

# --- reconstruct (Pi_Rec) ------------------------------------------------
result = ring.decode(PR.reconstruct(ctx, r))
print("secure relu(A @ B) =\n", np.asarray(result).round(3))
print("max |err| vs plaintext:",
      float(np.abs(np.asarray(result) - np.maximum(A @ B, 0)).max()))
print("\nMPC communication this program would send (per the paper's"
      " accounting):")
print(ctx.tally.summary())
print("\nmalicious checks passed:", not bool(ctx.abort_flag()))
