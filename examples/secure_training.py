"""End-to-end secure training driver (the paper's NN workload).

Trains the paper's 784-128-128-10 network on MNIST-like data under the
full 4PC protocol stack with checkpointing; prints accuracy + the online
communication a real deployment would pay per iteration.

    PYTHONPATH=src python examples/secure_training.py [--steps 300]
"""
import argparse
import time

import numpy as np

from repro.core.context import make_context
from repro.core.costs import LAN, WAN
from repro.nn.engine import TridentEngine
from repro.train import data as D, paper_ml as PML, checkpoint as CK
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--features", type=int, default=784)
    ap.add_argument("--ckpt", default="/tmp/trident_nn_ckpt")
    args = ap.parse_args()

    net = PML.MLPNet(features=args.features, layers=(128, 128, 10))
    data = D.MNISTLike(n=8192, seed=0, features=args.features)
    rng = np.random.RandomState(0)

    ctx = make_context(seed=0)
    eng = TridentEngine(ctx)
    params = {k: eng.from_plain(v)
              for k, v in PML.mlp_net_init(rng, net).items()}

    accs = []

    def step_fn(params, step, X, onehot, labels):
        new_params, probs = PML.mlp_net_step(
            eng, params, net, eng.from_plain(X), onehot, lr=0.25)
        acc = float(np.mean(np.argmax(
            np.asarray(eng.to_plain(probs)), -1) == labels))
        accs.append(acc)
        return new_params, 1.0 - acc, ctx.abort_flag()

    tr = Trainer(TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt,
                               ckpt_every=50), step_fn, params,
                 lambda s: data.batch(s, args.batch))
    t0 = time.time()
    tr.run()
    dt = time.time() - t0

    # per-iteration online cost of ONE iteration (fresh tally)
    c2 = make_context(seed=1)
    e2 = TridentEngine(c2)
    p2 = {k: e2.from_plain(v) for k, v in PML.mlp_net_init(rng, net).items()}
    X, onehot, _ = data.batch(0, args.batch)
    PML.mlp_net_step(e2, p2, net, e2.from_plain(X), onehot, 0.25)
    r, b = c2.tally.online.rounds, c2.tally.online.bits

    print(f"\ntrained {args.steps} secure iterations in {dt:.1f}s "
          f"(joint simulation on CPU)")
    print(f"accuracy: first10={np.mean(accs[:10]):.3f} "
          f"last10={np.mean(accs[-10:]):.3f}")
    print(f"online cost/iter: {r} rounds, {b/8e6:.2f} MB "
          f"-> LAN {LAN.seconds(r, b)*1e3:.1f} ms, WAN {WAN.seconds(r, b):.2f} s")
    print(f"events: {tr.events[-3:]}")


if __name__ == "__main__":
    main()
