"""Open-ended secure SGD with LIVE prep streaming into running daemons.

The deployment story PR 3/4 could not tell: there, a ``PartyCluster``'s
PrepBank was frozen at daemon startup (``deal_training_bank`` up front,
``prep_path=`` at spawn), so the number of training steps had to be known
before the mesh came up.  Here the cluster starts with an EMPTY bank and
a ``DealerDaemon`` -- a separate OS process wrapping ``ContinuousDealer``
-- streams session t's offline material over the cluster's per-rank
control queues while step t-1 runs online:

    dealer process ──(control queue, mp)──> party daemon's LivePrepBank
                                                  │  (watermark, bounded
                                                  │   look-ahead)
    driver ──submit(prep="bank", session=t)──> task blocks until session
                                               t arrives, then runs
                                               ONLINE-ONLY on the mesh

The TCP mesh never carries an offline byte (transport-enforced: offline
sends raise during the task), and the (params, loss) trajectory is
bit-identical to the joint simulation from the same step-indexed seeds.

    PYTHONPATH=src python examples/secure_training_live_prep.py
"""
import time

import numpy as np

from repro.train import data as D
from repro.train import secure_sgd as SGD
from repro.runtime.net.cluster import PartyCluster

SEED = 17
STEPS = 4
BATCH = 8

task = SGD.logreg_task(features=6, lr=0.5)
data = D.RegressionData(features=6, n=256, seed=1, logistic=True)
params0 = task.init_params(seed=0)


def main():
    print(f"live-streamed secure logreg SGD, {STEPS} steps, batch {BATCH} "
          f"(step seeds {SEED}+t)\n")

    # reference: the joint simulation, step-indexed seeds
    p_joint, l_joint = dict(params0), []
    for step in range(STEPS):
        p_joint, loss, _ = SGD.run_step(task, p_joint,
                                        data.batch(step, BATCH), step=step,
                                        base_seed=SEED, world="joint")
        l_joint.append(loss)
    print(f"[joint sim]     losses {['%.6f' % l for l in l_joint]}")

    t0 = time.time()
    with PartyCluster(live_prep=True) as cluster:
        # the daemons are up, their banks EMPTY -- now attach the dealer
        # (total=None would stream for as long as training runs)
        with SGD.attach_live_dealer(cluster, task, params0,
                                    data.batch(0, BATCH), base_seed=SEED,
                                    ahead=2, total=STEPS) as dealer:
            sgd = SGD.ClusterSGD(cluster, task, base_seed=SEED,
                                 prep="live")
            p_live, l_live = dict(params0), []
            for step in range(STEPS):
                p_live, loss, abort = sgd.step_fn(p_live, step,
                                                  *data.batch(step, BATCH))
                assert not abort
                l_live.append(loss)
                wall = max(r.wall_s for r in sgd.results[-1])
                print(f"[live 4-proc]   step {step}: loss {loss:.6f} "
                      f"online {wall*1e3:6.1f} ms "
                      f"(dealer watermark {dealer.dealt})")
            offline_bits = sgd.offline_bits_on_mesh()
    wall = time.time() - t0

    assert l_live == l_joint
    for k in p_joint:
        assert np.array_equal(np.asarray(p_joint[k]),
                              np.asarray(p_live[k]))
    assert offline_bits == 0
    print(f"\nbank started EMPTY; all {STEPS} sessions streamed over the "
          "control channel;")
    print(f"offline bits on the TCP mesh: {offline_bits} "
          "(transport-enforced)")
    print(f"trajectory BIT-IDENTICAL to the joint simulation "
          f"(cluster wall {wall:.1f}s)")


if __name__ == "__main__":
    main()
