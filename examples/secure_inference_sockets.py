"""Secure NN inference across four OS processes over TCP.

The acceptance demo of the distributed transport subsystem: a small MLP
with fused-truncation linear layers and real nonlinear activations
(ReLU + sigmoid via the ported conversions) runs three ways --

  1. the joint simulation (one trace, analytic CostTally),
  2. the party-sliced runtime over the in-memory LocalTransport,
  3. four OS processes over SocketTransport (TCP mesh, framed messages),

and the script checks the reconstructed predictions are *bit-identical*
across all three, and that the bytes/rounds measured on the real wire
equal the in-memory measurement and the analytic tally.  A WAN network
model wraps the socket backend, so the run also reports modeled
wall-clock under the paper's WAN environment next to the measured
single-machine wall-clock.

    PYTHONPATH=src python examples/secure_inference_sockets.py
"""
import time

import numpy as np

from repro.core import activations as ACT
from repro.core import protocols as PR
from repro.core.context import make_context
from repro.core.ring import RING64
from repro.runtime import FourPartyRuntime
from repro.runtime import activations as RA
from repro.runtime import protocols as RT
from repro.runtime.net import WAN, run_four_parties

rng = np.random.RandomState(0)
D, H, O, BATCH = 12, 8, 3, 4
W1 = rng.randn(D, H) * 0.3
W2 = rng.randn(H, O) * 0.3
X = rng.randn(BATCH, D)
SEED = 17


def predict_parties(rt, rank):
    """share -> matmul_tr -> relu -> matmul_tr -> sigmoid -> reconstruct."""
    enc = RING64.encode
    xs = RT.share(rt, enc(X))
    w1 = RT.share(rt, enc(W1))
    w2 = RT.share(rt, enc(W2))
    h = RA.relu(rt, RT.matmul_tr(rt, xs, w1))
    out = RA.sigmoid(rt, RT.matmul_tr(rt, h, w2))
    return np.asarray(RT.reconstruct(rt, out)[rank])


def main():
    # 1. joint simulation (same program order as predict_parties, so the
    # PRF counter streams -- and hence every share -- line up exactly)
    ctx = make_context(RING64, seed=SEED)
    enc = RING64.encode
    xs, w1, w2 = (PR.share(ctx, enc(a)) for a in (X, W1, W2))
    h = ACT.relu(ctx, PR.matmul_tr(ctx, xs, w1))
    out = ACT.sigmoid(ctx, PR.matmul_tr(ctx, h, w2))
    ref = np.asarray(PR.reconstruct(ctx, out))

    # 2. party-sliced runtime, in-memory transport
    rt = FourPartyRuntime(RING64, seed=SEED)
    local = predict_parties(rt, 1)
    assert np.array_equal(local, ref), "local runtime != joint simulation"
    assert rt.transport.totals() == ctx.tally.totals()
    print("joint == local runtime (bit-identical), measured == tally ✓")

    # 3. four OS processes over TCP, WAN network model on top
    t0 = time.time()
    results = run_four_parties(predict_parties, seed=SEED, timeout=300,
                               net_model=WAN)
    wall = time.time() - t0
    for res in results:
        assert np.array_equal(res.result, ref), f"P{res.rank} diverged"
        assert res.totals == rt.transport.totals(), f"P{res.rank} traffic"
        assert not res.abort
    print("socket (4 processes) == joint (bit-identical), "
          "wire bytes == tally ✓")

    t = results[0].totals
    print(f"\nmeasured on the TCP wire (each of 4 processes agrees):")
    for phase in ("offline", "online"):
        print(f"  {phase:7s} {t[phase]['rounds']:3d} rounds  "
              f"{t[phase]['bits']:8d} bits")
    m = results[0].modeled_s
    print(f"modeled WAN wall-clock: offline {m['offline']:.2f} s, "
          f"online {m['online']:.2f} s "
          f"(rtt {WAN.default.rtt_s*1e3:.0f} ms, "
          f"{WAN.default.bandwidth_bps/1e6:.0f} Mbps)")
    print(f"single-machine: {max(r.wall_s for r in results):.1f} s/party, "
          f"{wall:.1f} s end-to-end (spawn + JAX import dominated)")
    print("\nprediction sample (P1's reconstruction):")
    print(np.asarray(RING64.decode(results[1].result))[:2])


if __name__ == "__main__":
    main()
