"""Offline preprocessing walkthrough: dealer -> PrepStore -> online-only
executor -> pipelined serving.

Trident's offline-online paradigm, end to end:

  1. the DEALER walks the inference program's data-independent half ahead
     of time (only shapes needed -- zeros stand in for the inputs) and
     serializes per-party PrepStore material to disk;
  2. the ONLINE-ONLY executor later runs the same program from the store:
     the transport forbids offline-phase traffic (zero offline bytes,
     enforced), and the predictions are bit-identical to the interleaved
     path;
  3. the PIPELINED mode overlaps the two: a background dealer streams one
     store per batch into a bounded queue while batches execute
     online-only -- preprocessing leaves the serving critical path.

    PYTHONPATH=src python examples/secure_inference_offline.py
"""
import tempfile

import numpy as np

from repro.core.ring import RING64
from repro.offline import PrepStore, deal, run_online
from repro.runtime import FourPartyRuntime
from repro.runtime import activations as RA
from repro.runtime import protocols as RT
from repro.serve.party_server import PartyPredictionServer

SEED = 11
rng = np.random.RandomState(0)
W1 = rng.randn(6, 4) * 0.4
W2 = rng.randn(4, 2) * 0.4
X = rng.randn(3, 6)


def predict(rt, Xb):
    """share -> linear+trunc -> relu -> linear+trunc -> sigmoid -> open."""
    xs = RT.share(rt, RING64.encode(Xb))
    w1 = RT.share(rt, RING64.encode(W1))
    w2 = RT.share(rt, RING64.encode(W2))
    h = RA.relu(rt, RT.matmul_tr(rt, xs, w1))
    out = RA.sigmoid(rt, RT.matmul_tr(rt, h, w2))
    return RING64.decode(RT.reconstruct(rt, out)[1])


def program(rt):
    return predict(rt, X)


def main():
    # -- reference: the classic interleaved run ----------------------------
    rt = FourPartyRuntime(RING64, seed=SEED)
    want = np.asarray(program(rt))
    totals = rt.transport.totals()
    print(f"interleaved : offline {totals['offline']}, "
          f"online {totals['online']}")

    # -- 1. deal ahead of time (shapes only) and serialize -----------------
    store, drep = deal(lambda r: predict(r, np.zeros_like(X)),
                       ring=RING64, seed=SEED)
    prep_dir = tempfile.mkdtemp(prefix="prepstore-")
    store.save(prep_dir)
    print(f"dealer      : {drep.entries} entries, "
          f"{drep.offline_bits} offline bits in {drep.offline_rounds} "
          f"rounds -> {prep_dir}")
    print(f"              per-kind: {drep.summary}")

    # -- 2. online-only execution from the serialized store ----------------
    got, orep = run_online(program, PrepStore.load(prep_dir), ring=RING64)
    print(f"online-only : {orep.online_bits} online bits in "
          f"{orep.online_rounds} rounds, {orep.offline_bits} offline bits "
          f"(transport-enforced)")
    assert np.array_equal(np.asarray(got), want), "split changed the bits!"
    print("              predictions bit-identical to interleaved  [ok]")

    # -- 3. pipelined serving: background dealer + online-only batches -----
    srv = PartyPredictionServer(predict, batch_size=3, seed=SEED,
                                prep="pipelined")
    for q in rng.randn(6, 6):
        srv.submit(q)
    srv.flush()
    rep = srv.report()
    print(f"pipelined   : {rep['batches']} batches, "
          f"online-only {rep['online_only_ms_per_batch']:.1f} ms/batch "
          f"(offline dealt in background: "
          f"{rep['offline_deal_s_per_batch']*1e3:.1f} ms/batch), "
          f"offline bytes on the serving path: "
          f"{rep['offline_bits_per_batch']:.0f}")
    assert rep["offline_bits_per_batch"] == 0
    print("\nOffline material provisioned ahead -> the online phase is a "
          "standalone, measurable wall-clock.")


if __name__ == "__main__":
    main()
